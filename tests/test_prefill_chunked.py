"""Chunked prefill: fixed-shape prompt ingestion interleaved with decode.

Contracts under test:
- chunked prefill logits (and subsequent decode) are BIT-exact versus the
  monolithic `prefill` path across chunk sizes, non-divisor prompt lengths,
  and eviction churn — on GQA (olmoe) and MLA + shared experts (deepseek);
- the chunked path's jit compile count is independent of prompt-length
  diversity (the probe in `runtime.instrument` measures it);
- the serving scheduler interleaves prefill chunks with batched decode, so
  a long prompt neither starves co-batched decoders nor perturbs their
  outputs, and TTFT decomposes into queue/prefill/first-step;
- `predict_working_set` buckets prompt lengths (flat compiles, same
  estimate).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduce_config
from repro.configs.registry import get_config, get_smoke_config
from repro.runtime.engine import Engine, SlotBufferEngine
from repro.runtime.instrument import jit_cache_stats, track_compiles
from repro.runtime.request import Request
from repro.runtime.serving import EngineServingConfig, ServingEngine


# ---------------------------------------------------------------------------
# fast lane: instrument probe units
# ---------------------------------------------------------------------------

def test_jit_cache_stats_counts_compiled_specializations():
    import jax

    fns = {"a": jax.jit(lambda x: x + 1), "b": jax.jit(lambda x: x * 2),
           "plain": (lambda x: x)}          # non-jitted entries count 0
    fns["a"](jnp.ones(3))
    fns["a"](jnp.ones(4))                   # second shape -> second compile
    fns["b"](jnp.ones(3))
    stats = jit_cache_stats(fns)
    assert stats["entries"] == 3
    assert stats["compiles"] == 3


def test_track_compiles_reports_growth():
    import jax

    class FakeEngine:
        _fns = {}

    eng = FakeEngine()
    with track_compiles(eng) as probe:
        eng._fns["f"] = jax.jit(lambda x: x + 1)
        eng._fns["f"](jnp.ones(2))
    assert probe.new_entries == 1 and probe.new_compiles == 1
    with track_compiles(eng) as probe:
        eng._fns["f"](jnp.ones(2))          # warm call: no growth
    assert probe.new_entries == 0 and probe.new_compiles == 0


def test_request_metrics_ttft_attribution_identity():
    from repro.core.metrics import RequestMetrics
    m = RequestMetrics(request_id=0, arrival_s=1.0, admitted_s=1.5,
                       first_token_s=4.0, finish_s=6.0, n_tokens=3,
                       prefill_done_s=3.5)
    assert m.queue_delay_s == pytest.approx(0.5)
    assert m.prefill_s == pytest.approx(2.0)
    assert m.first_step_s == pytest.approx(0.5)
    assert m.ttft_s == pytest.approx(
        m.queue_delay_s + m.prefill_s + m.first_step_s)
    # unrecorded prefill completion (monolithic / simulator): prefill runs
    # to the first token and the identity still holds
    legacy = RequestMetrics(request_id=1, arrival_s=0.0, admitted_s=1.0,
                            first_token_s=3.0, finish_s=4.0, n_tokens=2)
    assert legacy.prefill_s == pytest.approx(2.0)
    assert legacy.first_step_s == 0.0
    assert legacy.ttft_s == pytest.approx(
        legacy.queue_delay_s + legacy.prefill_s + legacy.first_step_s)


# ---------------------------------------------------------------------------
# slow lane: real-engine chunked prefill
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chunk_setup():
    cfg = reduce_config(get_config("olmoe-1b-7b"), layers=4, d_model=64,
                        heads=4, kv_heads=4, d_ff=128, vocab=512, experts=8,
                        top_k=2, d_expert=32)
    eng = Engine(cfg, max_seq=96)
    return cfg, eng


def _slot_engine(cfg, eng, **kw):
    kw.setdefault("max_seq", 96)
    return SlotBufferEngine(cfg, eng.params, eng.model, **kw)


@pytest.mark.slow
def test_chunked_prefill_bit_exact_vs_monolithic_under_churn(chunk_setup):
    """THE chunked-prefill contract: with a slot buffer smaller than the
    expert population (real eviction churn), chunked logits AND the decode
    steps that follow match the monolithic path bitwise — across chunk
    sizes, including non-divisor prompt lengths and chunk > prompt."""
    cfg, eng = chunk_setup
    rng = np.random.default_rng(7)
    churn = dict(n_slots_per_layer=3, step_size=2)
    for T, C in ((7, 4), (12, 5), (16, 8), (9, 32), (24, 8)):
        prompt = rng.integers(0, cfg.vocab_size, (1, T)).astype(np.int32)
        mono = _slot_engine(cfg, eng, **churn)
        chun = _slot_engine(cfg, eng, **churn)
        lo_m, st_m = mono.prefill(prompt)
        lo_c, st_c = chun.prefill_chunked(prompt, chunk_size=C)
        np.testing.assert_array_equal(
            np.asarray(lo_m), np.asarray(lo_c),
            err_msg=f"prefill logits diverged at T={T} C={C}")
        tok = jnp.argmax(lo_m, -1).astype(jnp.int32)
        for step in range(4):
            lm, st_m = mono.decode_step(tok, st_m)
            lc, st_c = chun.decode_step(tok, st_c)
            np.testing.assert_array_equal(
                np.asarray(lm), np.asarray(lc),
                err_msg=f"decode diverged at T={T} C={C} step={step}")
            tok = jnp.argmax(lm, -1).astype(jnp.int32)
        assert chun.cache.stats.evictions > 0    # the cache really churned


@pytest.mark.slow
def test_chunked_prefill_bit_exact_on_mla_shared_expert_arch():
    """Same contract on MLA + shared experts + leading dense layer
    (deepseek-v2-lite smoke): the latent/pe-cache chunk path."""
    cfg = get_smoke_config("deepseek-v2-lite")
    eng = Engine(cfg, max_seq=48)
    rng = np.random.default_rng(2)
    kw = dict(n_slots_per_layer=cfg.moe.num_experts // 2, step_size=1,
              max_seq=48)
    for T, C in ((10, 4), (8, 3)):
        prompt = rng.integers(0, cfg.vocab_size, (1, T)).astype(np.int32)
        mono = SlotBufferEngine(cfg, eng.params, eng.model, **kw)
        chun = SlotBufferEngine(cfg, eng.params, eng.model, **kw)
        lo_m, st_m = mono.prefill(prompt)
        lo_c, st_c = chun.prefill_chunked(prompt, chunk_size=C)
        np.testing.assert_array_equal(np.asarray(lo_m), np.asarray(lo_c))
        tok = jnp.argmax(lo_m, -1).astype(jnp.int32)
        for _ in range(3):
            lm, st_m = mono.decode_step(tok, st_m)
            lc, st_c = chun.decode_step(tok, st_c)
            np.testing.assert_array_equal(np.asarray(lm), np.asarray(lc))
            tok = jnp.argmax(lm, -1).astype(jnp.int32)


@pytest.mark.slow
def test_chunked_prefill_compile_count_flat_across_lengths(chunk_setup):
    """After one warm prompt covering the longest KV-prefix bucket, four
    MORE distinct prompt lengths (divisor and non-divisor) compile NOTHING
    new on the chunked path — the jit cache is keyed on (chunk width, layer
    spec, log-bounded KV bucket) only. The monolithic path compiles per
    distinct length (the regression this PR removes)."""
    cfg, eng = chunk_setup
    rng = np.random.default_rng(11)
    # pin S: the adaptive controller may widen the pregate horizon, which
    # legitimately adds ONE fn per new S (bounded by s_max, not by lengths)
    chun = _slot_engine(cfg, eng, n_slots_per_layer=4, step_size=1)
    chun.prefill_chunked(
        rng.integers(0, cfg.vocab_size, (1, 33)).astype(np.int32),
        chunk_size=8)
    with track_compiles(chun) as probe:
        for T in (13, 17, 21, 29):
            chun.prefill_chunked(
                rng.integers(0, cfg.vocab_size, (1, T)).astype(np.int32),
                chunk_size=8)
    assert probe.new_compiles == 0 and probe.new_entries == 0

    mono = _slot_engine(cfg, eng, n_slots_per_layer=4, step_size=1)
    mono.prefill(rng.integers(0, cfg.vocab_size, (1, 33)).astype(np.int32))
    with track_compiles(mono) as probe:
        for T in (13, 17, 21, 29):
            mono.prefill(
                rng.integers(0, cfg.vocab_size, (1, T)).astype(np.int32))
    assert probe.new_compiles >= 4          # one-per-length: the baseline


@pytest.mark.slow
def test_long_prefill_not_starved_by_short_stream(chunk_setup):
    """Scheduler aging bound: a sustained stream of 1-token short requests
    (each a single chunk, retiring immediately, so a shorter cursor is
    nearly always in flight) cannot defer a long prompt's ingestion
    indefinitely — the starve limit forces the long cursor forward, so its
    prefill completes while shorts are still flowing, within its
    n_chunks * (limit + 1) iteration bound."""
    cfg, eng = chunk_setup
    rng = np.random.default_rng(21)
    long_req = Request(prompt=rng.integers(0, cfg.vocab_size, 32)
                       .astype(np.int32), max_new_tokens=2)
    shorts = [Request(prompt=rng.integers(0, cfg.vocab_size, 8)
                      .astype(np.int32), max_new_tokens=1, arrival_s=1e-3)
              for _ in range(32)]
    sb = _slot_engine(cfg, eng, n_slots_per_layer=4, step_size=1)
    srv = ServingEngine(sb, EngineServingConfig(max_batch=2,
                                                prefill_chunk=8))
    srv.serve([long_req] + shorts)
    assert len(long_req.output) == 2
    # without aging, SRF would hold the long cursor until the 32-short
    # stream drained; with it, the long prompt finishes ingesting while
    # shorts are still being served
    assert long_req.prefill_done_s < max(s.first_token_s for s in shorts)


@pytest.mark.slow
def test_serving_interleaves_decode_with_long_prefill(chunk_setup):
    """No decode starvation: while a long prompt ingests chunk-by-chunk, an
    already-decoding short request keeps emitting tokens — it FINISHES
    before the long prompt's prefill completes — and both requests' greedy
    outputs still match the single-request oracle. A later-admitted short
    prompt also overtakes the long cursor (shortest-remaining-first), so
    its TTFT is not head-of-line blocked."""
    cfg, eng = chunk_setup
    rng = np.random.default_rng(5)
    long_req = Request(prompt=rng.integers(0, cfg.vocab_size, 64)
                       .astype(np.int32), max_new_tokens=4)
    short_req = Request(prompt=rng.integers(0, cfg.vocab_size, 8)
                        .astype(np.int32), max_new_tokens=6)
    sb = _slot_engine(cfg, eng, n_slots_per_layer=4, step_size=1)
    srv = ServingEngine(sb, EngineServingConfig(max_batch=2,
                                                prefill_chunk=8))
    assert srv._chunked
    rep = srv.serve([long_req, short_req])
    # the short request decoded to completion BEFORE the long prompt was
    # even fully ingested: decode demonstrably interleaved with prefill
    assert short_req.finish_s < long_req.prefill_done_s
    # SRF: the short prompt's single chunk overtook the long cursor
    assert short_req.first_token_s < long_req.first_token_s
    ref = _slot_engine(cfg, eng, n_slots_per_layer=4, step_size=1)
    for r in (long_req, short_req):
        np.testing.assert_array_equal(
            np.asarray(r.output),
            ref.generate(r.prompt[None, :], r.max_new_tokens)[0])
    # TTFT attribution is coherent for every request
    for m in rep.requests:
        assert m.prefill_done_s >= 0
        assert m.prefill_s > 0 and m.first_step_s >= 0
        assert m.ttft_s == pytest.approx(
            m.queue_delay_s + m.prefill_s + m.first_step_s)


@pytest.mark.slow
def test_chunked_serving_matches_monolithic_serving_outputs(chunk_setup):
    """The scheduler change is output-invisible: the same request population
    served chunked and monolithic produces identical greedy tokens."""
    cfg, eng = chunk_setup
    outs = {}
    for chunk in (0, 8):
        rng = np.random.default_rng(9)
        reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, L)
                        .astype(np.int32), max_new_tokens=4)
                for L in (20, 8, 33, 8)]
        sb = _slot_engine(cfg, eng, n_slots_per_layer=4, step_size=1)
        ServingEngine(sb, EngineServingConfig(
            max_batch=3, prefill_chunk=chunk)).serve(reqs)
        outs[chunk] = [list(r.output) for r in reqs]
    assert outs[0] == outs[8]


@pytest.mark.slow
def test_predict_working_set_buckets_prompt_lengths(chunk_setup):
    """Admission estimates pad prompts to length buckets: distinct lengths
    within one bucket share ONE compiled specialization, and padding does
    not perturb the estimate itself."""
    cfg, eng = chunk_setup
    rng = np.random.default_rng(13)
    sb = _slot_engine(cfg, eng, n_slots_per_layer=4)
    srv = ServingEngine(sb, EngineServingConfig(max_batch=2))
    prompt = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    ws = srv.predict_working_set(Request(prompt=prompt))
    # oracle: the unbucketed computation in plain numpy — padding rows must
    # not perturb the distinct-expert counts
    x = np.asarray(eng.model.embed(eng.params, prompt[None, :])[0],
                   np.float32)
    want = np.mean([len({int(e) for e in
                         np.argsort(-(x @ r), axis=-1)[:, :cfg.moe.top_k]
                         .reshape(-1)})
                    for r in np.asarray(sb._router_stack, np.float32)])
    assert ws == pytest.approx(float(want))
    fn = sb._fns["predict_ws"]
    with track_compiles(sb) as probe:
        for L in (9, 10, 12, 15, 16):      # all bucket to 16
            srv.predict_working_set(
                Request(prompt=rng.integers(0, cfg.vocab_size, L)
                        .astype(np.int32)))
    assert probe.new_compiles == 0
    assert fn._cache_size() == 1
