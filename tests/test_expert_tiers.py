"""Tiered expert store: shard format round-trips, host staging tier
semantics (budget/LRU/pins), gather_many staging-buffer regression, and
bit-exact engine serving through the disk->host->device chain."""
import json
import os

import ml_dtypes
import numpy as np
import pytest

from repro.core.expert_buffer import HostExpertStore
from repro.core.expert_tiers import (SHARD_MANIFEST, ExpertShardReader,
                                     HostTierModel, ShardError,
                                     TieredExpertStore, export_expert_shards)


def _store(rng, layers=2, experts=4, dtype=np.float32, d=6, f=10):
    st = HostExpertStore()
    for li in range(layers):
        wg = rng.standard_normal((experts, d, f)).astype(np.float32)
        wu = rng.standard_normal((experts, d, f)).astype(np.float32)
        wd = rng.standard_normal((experts, f, d)).astype(np.float32)
        st.add_layer(li, wg.astype(dtype), wu.astype(dtype), wd.astype(dtype))
    return st


def _bits(a):
    """Raw-storage view so exotic dtypes compare bitwise, NaNs included."""
    return np.asarray(a).view(np.uint8 if a.dtype.itemsize == 1
                              else np.uint16 if a.dtype.itemsize == 2
                              else np.uint32)


# --------------------------------------------------------------------------
# shard format round-trips
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.float32, ml_dtypes.bfloat16,
                                   ml_dtypes.float8_e4m3fn])
def test_shard_roundtrip_bitwise(tmp_path, dtype):
    rng = np.random.default_rng(0)
    st = _store(rng, dtype=dtype)
    export_expert_shards(st, str(tmp_path / "sh"))
    rd = ExpertShardReader(str(tmp_path / "sh"))
    assert rd.layers() == [0, 1]
    for li in range(2):
        for e in range(4):
            got = rd.read_expert(li, e)
            want = st.gather(li, [e])
            for g, w in zip(got, want):
                assert g.dtype == w.dtype
                np.testing.assert_array_equal(_bits(g), _bits(w[0]))


def test_shard_noncontiguous_subset_and_cross_layer_gather(tmp_path):
    rng = np.random.default_rng(1)
    st = _store(rng, layers=3, experts=8)
    tiered = TieredExpertStore(
        export_expert_shards(st, str(tmp_path / "sh")))
    # non-contiguous, unordered subset within one layer
    subset = [6, 1, 3]
    for key in [(1, e) for e in subset]:
        assert tiered.demand_host(key, 0.0) is not None
    for g, w in zip(tiered.gather(1, subset), st.gather(1, subset)):
        np.testing.assert_array_equal(g, w)
    # gather_many spanning layers in interleaved order
    keys = [(0, 5), (2, 0), (1, 6), (0, 2), (2, 7)]
    for key in keys:
        assert tiered.demand_host(key, 0.0) is not None
    for g, w in zip(tiered.gather_many(keys), st.gather_many(keys)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_gather_before_residency_is_a_scheduling_bug(tmp_path):
    st = _store(np.random.default_rng(2))
    tiered = TieredExpertStore(export_expert_shards(st, str(tmp_path / "s")))
    with pytest.raises(RuntimeError, match="not staged"):
        tiered.gather(0, [0])


def test_truncated_and_corrupt_shards_raise_shard_error(tmp_path):
    st = _store(np.random.default_rng(3))
    sdir = export_expert_shards(st, str(tmp_path / "sh"))

    # truncated .bin -> ShardError at open
    import shutil
    t1 = str(tmp_path / "trunc")
    shutil.copytree(sdir, t1)
    binf = os.path.join(t1, "layer_00000.bin")
    with open(binf, "r+b") as f:
        f.truncate(os.path.getsize(binf) - 8)
    with pytest.raises(ShardError, match="truncated"):
        ExpertShardReader(t1)

    # manifest with inconsistent tensor byte counts -> ShardError
    t2 = str(tmp_path / "badman")
    shutil.copytree(sdir, t2)
    man = json.load(open(os.path.join(t2, SHARD_MANIFEST)))
    man["layers"][0]["tensors"][0]["nbytes"] += 4
    json.dump(man, open(os.path.join(t2, SHARD_MANIFEST), "w"))
    with pytest.raises(ShardError):
        ExpertShardReader(t2)

    # missing shard file -> ShardError
    t3 = str(tmp_path / "miss")
    shutil.copytree(sdir, t3)
    os.remove(os.path.join(t3, "layer_00001.bin"))
    with pytest.raises(ShardError, match="missing"):
        ExpertShardReader(t3)

    # unparsable manifest -> ShardError
    t4 = str(tmp_path / "nojson")
    shutil.copytree(sdir, t4)
    with open(os.path.join(t4, SHARD_MANIFEST), "w") as f:
        f.write("{not json")
    with pytest.raises(ShardError):
        ExpertShardReader(t4)


def test_truncation_after_open_fails_at_materialization(tmp_path):
    # The reader maps shard files lazily, so a file can shrink between
    # construction and first read (partial re-export, disk fault). A read
    # that lands inside the truncated tail must raise ShardError, not
    # silently return short/garbage bytes.
    st = _store(np.random.default_rng(4))
    sdir = export_expert_shards(st, str(tmp_path / "sh"))
    rd = ExpertShardReader(sdir)          # no reads yet: mmap still lazy
    binf = os.path.join(sdir, "layer_00001.bin")
    rec = rd.record_nbytes(1)
    # cut inside record k=2 (a mid-file record, not just the last one)
    with open(binf, "r+b") as f:
        f.truncate(2 * rec + rec // 2)
    with pytest.raises(ShardError, match="truncated"):
        rd.read_expert(1, 2)
    rd.read_expert(1, 0)                  # records before the cut still fine
    with pytest.raises(ShardError, match="truncated"):
        rd.read_expert(1, 3)


def test_manifest_checksums_stamped_and_optional(tmp_path):
    import zlib
    st = _store(np.random.default_rng(5))
    sdir = export_expert_shards(st, str(tmp_path / "sh"))
    rd = ExpertShardReader(sdir)
    assert rd.has_checksums()
    for li in rd.layers():
        for e in range(rd.num_experts(li)):
            want = rd.record_crc(li, e)
            got = zlib.crc32(rd.read_record_bytes(li, e).tobytes())
            assert got == want
    # pre-checksum manifests (no crc32 field) still load; verification
    # silently downgrades to off rather than refusing the store
    man_path = os.path.join(sdir, SHARD_MANIFEST)
    man = json.load(open(man_path))
    for rec in man["layers"]:
        del rec["crc32"]
    json.dump(man, open(man_path, "w"))
    rd2 = ExpertShardReader(sdir)
    assert not rd2.has_checksums()
    assert rd2.record_crc(0, 0) is None
    store = TieredExpertStore(sdir, verify="promote")
    assert store.verify == "off"


# --------------------------------------------------------------------------
# host staging tier: budget, LRU, pins
# --------------------------------------------------------------------------

def _tier(budget_experts, **kw):
    kw.setdefault("disk_bandwidth", 1e12)  # effectively instant promotions
    return HostTierModel(num_layers=2, num_experts=8, expert_nbytes=1000.0,
                         host_budget_bytes=budget_experts * 1000.0, **kw)


def test_budget_lru_eviction_order():
    m = _tier(2)
    for e in range(3):                      # third demand evicts LRU (0,0)
        assert m.demand((0, e), float(e)) is not None
    assert not m.host_resident((0, 0))
    assert m.host_resident((0, 1)) and m.host_resident((0, 2))
    assert m.evictions == 1 and m.host_bytes == 2000.0
    # touching (0,1) makes (0,2) the LRU victim for the next promotion
    assert m.demand((0, 1), 3.0) == (0.0, True)
    assert m.demand((0, 3), 4.0) is not None
    assert not m.host_resident((0, 2)) and m.host_resident((0, 1))


def test_pinned_expert_survives_eviction_churn():
    m = _tier(2)
    assert m.demand((0, 0), 0.0) is not None
    m.pin((0, 0))
    for e in range(1, 6):                   # churn through the other slot
        assert m.demand((0, e), float(e)) is not None
        assert m.host_resident((0, 0)), f"pinned entry evicted at e={e}"
    m.unpin((0, 0))
    assert m.demand((0, 6), 9.0) is not None
    assert not m.host_resident((0, 0))      # evictable again after unpin


def test_demand_overflows_budget_when_all_residents_pinned():
    """Forward progress beats the budget: a demand promotion into a fully
    pinned tier lands anyway (transient overflow), it never deadlocks."""
    m = _tier(1)
    assert m.demand((0, 0), 0.0) is not None
    m.pin((0, 0))
    assert m.demand((0, 1), 1.0) is not None
    assert m.host_resident((0, 0)) and m.host_resident((0, 1))
    assert m.host_bytes == 2000.0           # over budget, by design


def test_disk_prefetch_converts_misses_to_hits():
    m = _tier(8, disk_bandwidth=1e6, prefetch=True)
    m.note_layer_demand(2)
    for e in range(4):
        m.note_predicted([(0, e)])
        m.request((0, e), 0.0)
    m.advance(10.0)                         # promotions land
    for e in range(4):
        stall, hit = m.demand((0, e), 10.0)
        assert hit and stall == 0.0
    assert m.host_hits == 4 and m.host_misses == 0


# --------------------------------------------------------------------------
# gather_many staging buffer regression (satellite b)
# --------------------------------------------------------------------------

def test_gather_many_staging_buffer_bit_exact_and_reused():
    rng = np.random.default_rng(7)
    for dtype in (np.float32, ml_dtypes.bfloat16):
        st = _store(rng, layers=3, experts=8, dtype=dtype)

        def naive(keys):
            outs = [st.gather(li, [e]) for li, e in keys]
            return tuple(np.concatenate([o[t] for o in outs])
                         for t in range(3))

        k1 = [(0, 3), (0, 5), (1, 1), (2, 7), (2, 0)]
        got1 = st.gather_many(k1)
        for g, w in zip(got1, naive(k1)):
            np.testing.assert_array_equal(_bits(g), _bits(w))
        got1 = tuple(np.array(g) for g in got1)   # copy before reuse

        # second call with the same padded shape reuses the SAME buffer
        k2 = [(2, 2), (1, 4), (0, 0), (1, 6), (0, 7)]
        got2 = st.gather_many(k2)
        for g, w in zip(got2, naive(k2)):
            np.testing.assert_array_equal(_bits(g), _bits(w))
        # first result copies are unaffected by the buffer reuse
        for g, w in zip(got1, naive(k1)):
            np.testing.assert_array_equal(_bits(g), _bits(w))
        assert len(st._staging) == 1          # one signature -> one buffer

        # single-layer call keeps the fancy-index fast path
        for g, w in zip(st.gather_many([(1, 2), (1, 5)]),
                        st.gather(1, [2, 5])):
            np.testing.assert_array_equal(_bits(g), _bits(w))


# --------------------------------------------------------------------------
# bit-exact engine serving through the tier under eviction churn
# --------------------------------------------------------------------------

def _greedy_tokens(sb, prompt, n_steps):
    import jax.numpy as jnp
    lo, st = sb.prefill(prompt)
    tok = jnp.argmax(lo, -1).astype(jnp.int32)
    toks = [int(tok[0])]
    for _ in range(n_steps):
        lo, st = sb.decode_step(tok, st)
        tok = jnp.argmax(lo, -1).astype(jnp.int32)
        toks.append(int(tok[0]))
    return toks


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "deepseek-v2-lite"])
def test_engine_bit_exact_through_tier_at_half_budget(tmp_path, arch):
    """SlotBufferEngine on a TieredExpertStore with a host budget of ~50%
    of total expert bytes produces bit-exact greedy tokens vs the
    pre-staged HostExpertStore, under host-tier eviction churn (GQA and
    MLA architectures)."""
    from repro.configs.registry import get_smoke_config
    from repro.runtime.engine import (Engine, SlotBufferEngine,
                                      build_host_store)
    cfg = get_smoke_config(arch)
    eng = Engine(cfg, max_seq=48)
    kw = dict(n_slots_per_layer=2, step_size=1, max_seq=48)
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, (1, 8)).astype(np.int32)

    ref = SlotBufferEngine(cfg, eng.params, eng.model, **kw)
    want = _greedy_tokens(ref, prompt, 6)

    sdir = export_expert_shards(build_host_store(eng.model, eng.params),
                                str(tmp_path / arch))
    store = TieredExpertStore(
        sdir, host_budget_bytes=0.5 * TieredExpertStore(sdir).
        total_expert_bytes)
    sb = SlotBufferEngine(cfg, eng.params, eng.model, store=store, **kw)
    got = _greedy_tokens(sb, prompt, 6)
    assert got == want
    snap = store.snapshot()
    assert snap["evictions"] > 0, "no host-tier churn: budget too generous"
    assert sb.stats.host_hits + sb.stats.host_misses > 0
