"""SlotTable invariants + batched swap_in_many vs sequential swap_in."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core.expert_buffer import (HostExpertStore, SlotTable, make_buffer,
                                      swap_in, swap_in_many)


# ---------------------------------------------------------------------------
# SlotTable invariants
# ---------------------------------------------------------------------------

def test_slot_table_assign_release_roundtrip():
    t = SlotTable(num_layers=2, num_experts=4, n_slots=3)
    s = t.assign(0, 2)
    assert t.lookup(0, 2) == s
    assert t.key_of_slot[s] == (0, 2)
    assert t.n_resident == 1
    released = t.release(0, 2)
    assert released == s
    assert t.lookup(0, 2) == -1
    assert t.key_of_slot[s] is None
    assert t.n_resident == 0
    # the slot is reusable after release
    s2 = t.assign(1, 0)
    assert 0 <= s2 < 3


def test_slot_table_free_list_never_double_assigns():
    t = SlotTable(num_layers=2, num_experts=8, n_slots=4)
    taken = [t.assign(0, e) for e in range(4)]
    assert sorted(taken) == [0, 1, 2, 3]       # each slot handed out once
    with pytest.raises(RuntimeError):
        t.assign(1, 0)                          # exhausted -> must refuse
    t.release(0, 1)
    s = t.assign(1, 5)
    assert s == taken[1]                        # freed slot is the one reused
    # releasing and re-assigning repeatedly never yields a duplicate
    seen = {t.lookup(0, 0), t.lookup(0, 2), t.lookup(0, 3), s}
    assert len(seen) == 4


def test_slot_table_layer_isolation():
    t = SlotTable(num_layers=3, num_experts=4, n_slots=6)
    t.assign(0, 1)
    t.assign(1, 1)
    m0, m1, m2 = (t.layer_slot_map(i) for i in range(3))
    assert m0[1] >= 0 and m1[1] >= 0 and m0[1] != m1[1]
    assert (m2 == -1).all()
    # the returned map is a COPY: mutating it cannot corrupt the table
    m0[:] = 99
    assert t.lookup(0, 1) != 99
    # releasing in one layer leaves the other layer's mapping intact
    t.release(0, 1)
    assert t.lookup(0, 1) == -1 and t.lookup(1, 1) >= 0


# ---------------------------------------------------------------------------
# swap_in_many == sequential swap_in (bitwise)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_moved", [1, 3, 4, 7])
def test_swap_in_many_matches_sequential(n_moved):
    cfg = get_smoke_config("olmoe-1b-7b")
    d, f = cfg.d_model, cfg.moe.d_expert
    n_slots = 8
    rng = np.random.default_rng(n_moved)
    wg = jnp.asarray(rng.standard_normal((n_moved, d, f)), jnp.bfloat16)
    wu = jnp.asarray(rng.standard_normal((n_moved, d, f)), jnp.bfloat16)
    wd = jnp.asarray(rng.standard_normal((n_moved, f, d)), jnp.bfloat16)
    slots = rng.permutation(n_slots)[:n_moved]

    seq = make_buffer(cfg, n_slots)
    for i, s in enumerate(slots):
        seq = swap_in(seq, int(s), wg[i], wu[i], wd[i])
    batched = swap_in_many(make_buffer(cfg, n_slots), slots, wg, wu, wd)
    for k in ("w_gate", "w_up", "w_down"):
        np.testing.assert_array_equal(np.asarray(seq[k], np.float32),
                                      np.asarray(batched[k], np.float32))


def test_swap_in_many_overwrites_previous_occupant():
    cfg = get_smoke_config("olmoe-1b-7b")
    d, f = cfg.d_model, cfg.moe.d_expert
    rng = np.random.default_rng(0)
    old = jnp.asarray(rng.standard_normal((1, d, f)), jnp.bfloat16)
    new = jnp.asarray(rng.standard_normal((1, d, f)), jnp.bfloat16)
    old_d = jnp.asarray(rng.standard_normal((1, f, d)), jnp.bfloat16)
    new_d = jnp.asarray(rng.standard_normal((1, f, d)), jnp.bfloat16)
    buf = make_buffer(cfg, 2)
    buf = swap_in_many(buf, [1], old, old, old_d)
    buf = swap_in_many(buf, [1], new, new, new_d)
    np.testing.assert_array_equal(np.asarray(buf["w_gate"][1], np.float32),
                                  np.asarray(new[0], np.float32))


def test_host_expert_store_gathers_contiguous_views():
    rng = np.random.default_rng(3)
    E, d, f = 6, 8, 4
    wg = jnp.asarray(rng.standard_normal((E, d, f)), jnp.bfloat16)
    wu = jnp.asarray(rng.standard_normal((E, d, f)), jnp.bfloat16)
    wd = jnp.asarray(rng.standard_normal((E, f, d)), jnp.bfloat16)
    store = HostExpertStore()
    store.add_layer(0, wg, wu, wd)
    g_wg, g_wu, g_wd = store.gather(0, [4, 1])
    assert isinstance(g_wg, np.ndarray) and g_wg.flags["C_CONTIGUOUS"]
    np.testing.assert_array_equal(np.asarray(g_wg, np.float32),
                                  np.asarray(wg[jnp.asarray([4, 1])],
                                             np.float32))
    np.testing.assert_array_equal(np.asarray(g_wd, np.float32),
                                  np.asarray(wd[jnp.asarray([4, 1])],
                                             np.float32))
