"""Simulator behaviour tests: policy ordering, oracle ceiling, paper shapes."""
import numpy as np
import pytest

from repro.core import baseline, expertflow, pregate_fixed, promoe_like
from repro.core.coordinator import ablation
from repro.simulator.events import RoutingTrace, SimSpec, StepTrace, simulate
from repro.simulator.hardware import PLATFORMS


def synthetic_trace(L=6, M=16, steps=20, T=4, d=8, seed=0, locality=0.8):
    """Synthetic routing with temporal locality: each step reuses the
    previous step's experts with prob `locality`."""
    rng = np.random.default_rng(seed)
    routers = [rng.standard_normal((d, M)).astype(np.float32) * 0.3
               for _ in range(L)]
    tr = RoutingTrace("synthetic", L, M, top_k=2, routers=routers)
    prev = rng.integers(0, M, (L, T, 2))
    for s in range(steps):
        assigns = []
        for l in range(L):
            cur = prev[l].copy()
            mask = rng.random(cur.shape) > locality
            cur[mask] = rng.integers(0, M, mask.sum())
            assigns.append(cur)
        prev = np.stack(assigns)
        hidden = rng.standard_normal((L, d)).astype(np.float32)
        tr.steps.append(StepTrace(s, rng.integers(0, 64, 8), list(prev),
                                  hidden, rng.standard_normal((T, d))))
    return tr


@pytest.fixture(scope="module")
def trace():
    return synthetic_trace()


def _sim(capacity_frac=0.9, layer_ms=1.0, expert_mb=17.0, L=6, M=16):
    return SimSpec(expert_bytes=expert_mb * 1e6, layer_time_s=layer_ms * 1e-3,
                   capacity_experts=int(L * M * capacity_frac))


def test_oracle_reaches_zero_steady_state_stall(trace):
    hw = PLATFORMS["a6000"]
    pol = ablation("oracle", predictor="oracle", adaptive_s=False, fixed_s=3)
    rep = simulate(trace, _sim(), hw, pol)
    # after warmup (step 0 cold start), stalls must vanish
    steady = rep.steps[2:]
    assert sum(s.stall_s for s in steady) == pytest.approx(0.0, abs=1e-9)


def test_prefetch_beats_no_prefetch(trace):
    hw = PLATFORMS["a6000"]
    base = simulate(trace, _sim(), hw, baseline())
    orac = simulate(trace, _sim(), hw,
                    ablation("oracle", predictor="oracle"))
    assert orac.total_stall_s < base.total_stall_s


def test_expertflow_cache_aware_reduces_stall(trace):
    hw = PLATFORMS["a6000"]
    on = simulate(trace, _sim(capacity_frac=0.6), hw, expertflow())
    off = simulate(trace, _sim(capacity_frac=0.6), hw,
                   ablation("no_cache_aware", cache_aware=False))
    assert on.total_stall_s <= off.total_stall_s + 1e-9


def test_slow_link_increases_stall(trace):
    fast = simulate(trace, _sim(), PLATFORMS["h20"], baseline())
    slow = simulate(trace, _sim(), PLATFORMS["rx6500xt"], baseline())
    assert slow.total_stall_s > fast.total_stall_s


def test_adaptive_s_stays_in_bounds(trace):
    hw = PLATFORMS["rtx4090"]
    rep = simulate(trace, _sim(capacity_frac=0.5), hw, expertflow())
    cfg = expertflow().step_cfg
    for s in rep.steps:
        assert cfg.s_min <= s.step_size <= cfg.s_max


def test_tiny_capacity_thrashes(trace):
    """Fig 10 phenomenon: capacity below working set -> misses explode."""
    hw = PLATFORMS["a6000"]
    big = simulate(trace, _sim(capacity_frac=1.0), hw, expertflow())
    tiny = simulate(trace, _sim(capacity_frac=0.15), hw, expertflow())
    assert tiny.total_cache_miss_s > big.total_cache_miss_s


def test_summary_fields(trace):
    rep = simulate(trace, _sim(), PLATFORMS["a6000"], promoe_like(2))
    s = rep.summary()
    for k in ("stall_s", "compute_s", "hit_rate", "mean_step_size"):
        assert k in s
    assert s["total_s"] >= s["compute_s"]
