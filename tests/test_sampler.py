"""Sampler vector-temperature contracts: all-greedy vector batches must be
bitwise-identical to scalar greedy, greedy rows in mixed batches must be
independent of the shared key, and `sample_rows` must accept raw (B, 2)
uint32 key data alongside typed PRNG keys."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.sampler import sample, sample_rows


def _logits(B=4, V=32, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(B, V)) * 3, jnp.float32)


def test_all_greedy_vector_matches_scalar_greedy_bitwise():
    """temperature=zeros(B) takes the same argmax path as scalar 0.0 for
    every row — the serving loop's all-greedy fast path and the vector
    mode must agree exactly."""
    logits = _logits()
    key = jax.random.PRNGKey(3)
    scalar = np.asarray(sample(logits, key, 0.0))
    vector = np.asarray(sample(logits, key, jnp.zeros(logits.shape[0])))
    np.testing.assert_array_equal(scalar, vector)
    assert vector.dtype == np.int32
    np.testing.assert_array_equal(
        vector, np.asarray(jnp.argmax(logits, axis=-1)))


def test_mixed_batch_greedy_rows_ignore_shared_key():
    """In `sample`'s vector mode all sampled rows draw from ONE shared key;
    a greedy row (t <= 0) must come out as its argmax regardless of which
    key the batch happens to carry or which neighbours are sampling."""
    logits = _logits(B=3)
    temps = jnp.asarray([0.0, 1.5, 0.0])
    a = np.asarray(sample(logits, jax.random.PRNGKey(0), temps))
    b = np.asarray(sample(logits, jax.random.PRNGKey(12345), temps))
    greedy = np.asarray(jnp.argmax(logits, axis=-1))
    for row in (0, 2):
        assert a[row] == b[row] == greedy[row]


def test_sample_rows_accepts_raw_uint32_key_data():
    """The continuous batcher stacks per-row keys; whether they arrive as
    typed PRNG keys or raw (B, 2) uint32 key data, the drawn tokens must
    match (PRNGKey(n) wraps the raw words [0, n])."""
    logits = _logits(B=3, seed=7)
    temps = jnp.asarray([0.9, 0.0, 1.7])
    typed = jnp.stack([jax.random.PRNGKey(n) for n in (42, 7, 99)])
    raw = jnp.asarray(np.array([[0, 42], [0, 7], [0, 99]], np.uint32))
    out_typed = np.asarray(sample_rows(logits, typed, temps))
    out_raw = np.asarray(sample_rows(logits, raw, temps))
    np.testing.assert_array_equal(out_typed, out_raw)
    # the greedy row is the argmax either way
    assert out_raw[1] == int(jnp.argmax(logits[1]))
    assert out_raw.dtype == np.int32
