"""Unit tests for the HLO collective parser and loop-aware accounting —
the machinery the roofline terms depend on."""
import textwrap

import pytest

from repro.launch.hlo import (CollectiveStats, _collective_of_line,
                              _group_size, _shape_bytes, _split_computations,
                              _trip_count, collective_stats,
                              loop_aware_collective_stats)


def test_shape_bytes():
    assert _shape_bytes("bf16", "16,4096") == 16 * 4096 * 2
    assert _shape_bytes("f32", "") == 4
    assert _shape_bytes("s8", "10") == 10


def test_group_size_forms():
    assert _group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert _group_size("replica_groups=[16,16]<=[256]") == 16
    assert _group_size("no groups here") == 1


def test_collective_of_line_kinds():
    line = ("  %all-reduce.1 = f32[128,64]{1,0} all-reduce(%x), "
            "replica_groups={{0,1}}, to_apply=%add")
    kind, nbytes = _collective_of_line(line)
    assert kind == "all-reduce"
    assert nbytes == 128 * 64 * 4
    # all-gather: operand = result / group size
    line = ("  %ag = bf16[64,32]{1,0} all-gather(%x), "
            "replica_groups={{0,1,2,3}}, dimensions={0}")
    kind, nbytes = _collective_of_line(line)
    assert kind == "all-gather"
    assert nbytes == 64 * 32 * 2 // 4
    # reduce-scatter: operand = result * group size
    line = ("  %rs = f32[8]{0} reduce-scatter(%x), replica_groups={{0,1}}, "
            "to_apply=%add")
    kind, nbytes = _collective_of_line(line)
    assert kind == "reduce-scatter"
    assert nbytes == 8 * 4 * 2


def test_non_collective_lines_ignored():
    assert _collective_of_line("  %d = f32[2]{0} dot(%a, %b)") is None
    assert _collective_of_line("random text") is None


_FAKE_HLO = textwrap.dedent("""\
    HloModule test

    %add (a: f32[], b: f32[]) -> f32[] {
      ROOT %r = f32[] add(%a, %b)
    }

    %cond (s: (s32[], f32[4])) -> pred[] {
      %iv = s32[] get-tuple-element(%s), index=0
      %limit = s32[] constant(10)
      ROOT %lt = pred[] compare(%iv, %limit), direction=LT
    }

    %body (s: (s32[], f32[4])) -> (s32[], f32[4]) {
      %x = f32[4]{0} get-tuple-element(%s), index=1
      %ar = f32[4]{0} all-reduce(%x), replica_groups={{0,1}}, to_apply=%add
      ROOT %t = (s32[], f32[4]) tuple(%iv, %ar)
    }

    ENTRY %main (p: f32[4]) -> f32[4] {
      %ar0 = f32[4]{0} all-reduce(%p), replica_groups={{0,1}}, to_apply=%add
      %w = (s32[], f32[4]) while(%init), condition=%cond, body=%body
      ROOT %out = f32[4]{0} get-tuple-element(%w), index=1
    }
    """)


def test_split_computations_and_trip_count():
    comps, entry = _split_computations(_FAKE_HLO)
    assert entry == "main"
    assert "body" in comps and "cond" in comps
    assert _trip_count(comps["cond"]) == 10


def test_flat_vs_loop_aware():
    flat = collective_stats(_FAKE_HLO)
    assert flat.count_by_kind["all-reduce"] == 2          # counted once each
    loop = loop_aware_collective_stats(_FAKE_HLO)
    # entry ar0 (x1) + body ar (x10 trips)
    assert loop.count_by_kind["all-reduce"] == 11
    assert loop.bytes_by_kind["all-reduce"] == 11 * 16


def test_merged_stats():
    a = CollectiveStats({"all-reduce": 10}, {"all-reduce": 1})
    b = CollectiveStats({"all-reduce": 5, "all-to-all": 7},
                        {"all-reduce": 2, "all-to-all": 1})
    m = a.merged(b)
    assert m.bytes_by_kind == {"all-reduce": 15, "all-to-all": 7}
    assert m.total_bytes == 22


def test_n_blocks_causal_and_window():
    from repro.launch.roofline import _n_blocks, Q_CHUNK, KV_CHUNK
    # full attention: all blocks
    assert _n_blocks(2048, 2048, causal=False) == \
        (2048 // Q_CHUNK) * (2048 // KV_CHUNK)
    # causal: roughly half + diagonal
    full = _n_blocks(4096, 4096, causal=False)
    causal = _n_blocks(4096, 4096, causal=True)
    assert full / 2 <= causal <= full * 0.8
    # window limits the band
    win = _n_blocks(32768, 32768, causal=True, window=2048)
    assert win < _n_blocks(32768, 32768, causal=True) * 0.2
