"""End-to-end behaviour tests for the paper's system.

Full loop: real model execution -> routing traces -> predictor training ->
latency simulation under baseline vs ExpertFlow, asserting the paper's
qualitative claims on a reduced-scale setup.
"""
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core import (FeatureSpec, ForestPredictor, baseline, expertflow,
                        pregate_fixed)
from repro.core.coordinator import ablation
from repro.core.predictor import PreGate, recall_accuracy
from repro.runtime.engine import Engine
from repro.simulator.events import SimSpec, simulate
from repro.simulator.hardware import PLATFORMS


pytestmark = pytest.mark.slow   # real-model end-to-end loop


@pytest.fixture(scope="module")
def pipeline():
    cfg = get_smoke_config("deepseek-v2-lite")
    eng = Engine(cfg, max_seq=128)
    toks = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 16))
    out, trace, log = eng.generate(toks, n_steps=16)
    spec = FeatureSpec(cfg.vocab_size, 8, trace.num_moe_layers,
                       trace.num_experts, include_pregate=True)
    forest = ForestPredictor(spec)
    forest.fit(log)
    return cfg, eng, trace, log, forest


def _spec(trace, frac=0.9):
    L, M = trace.num_moe_layers, trace.num_experts
    return SimSpec(expert_bytes=17.3e6, layer_time_s=1e-3,
                   capacity_experts=max(4, int(L * M * frac)))


def test_full_loop_runs_and_expertflow_beats_baseline(pipeline):
    cfg, eng, trace, log, forest = pipeline
    hw = PLATFORMS["a6000"]
    rep_base = simulate(trace, _spec(trace), hw, baseline())
    rep_ef = simulate(trace, _spec(trace), hw, expertflow(), forest=forest)
    assert rep_ef.total_stall_s < rep_base.total_stall_s
    assert rep_ef.hit_rate >= rep_base.hit_rate - 0.05


def test_oracle_eliminates_steady_state_stall(pipeline):
    """The paper's headline: stall -> ~0 when predictions are right and
    bandwidth suffices (<0.1% of baseline in their setting)."""
    cfg, eng, trace, log, forest = pipeline
    hw = PLATFORMS["h20"]
    pol = ablation("oracle", predictor="oracle", adaptive_s=False, fixed_s=3)
    rep = simulate(trace, _spec(trace, frac=1.0), hw, pol)
    steady = rep.steps[2:]
    assert sum(s.stall_s for s in steady) == pytest.approx(0.0, abs=1e-9)


def test_predictor_beats_pregate_on_trace(pipeline):
    """Paper §4.3: the trained predictor's recall exceeds raw pre-gating
    at distance S (evaluated on the engine's own traces)."""
    cfg, eng, trace, log, forest = pipeline
    pregate = PreGate(trace.routers)
    L = trace.num_moe_layers
    s = 1 if L <= 2 else 2   # the smoke model has 2 MoE layers
    acc_p, acc_g, n = 0.0, 0.0, 0
    for st in trace.steps[1:]:
        hist = np.zeros((L, trace.num_experts))
        for li in range(L - s):
            tgt = li + s
            actual = sorted({int(e) for e in st.assignments[tgt].reshape(-1)})
            k = max(len(actual), trace.top_k)
            hid = st.hidden_pooled[li][None, :]
            pg_probs = pregate.probs(hid, tgt)
            pred_g = np.argsort(pg_probs)[-k:]
            scores = forest.scores(st.token_ids, tgt, s, hist, pg_probs)
            pred_p = np.argsort(scores)[-k:]
            acc_g += recall_accuracy(pred_g, actual)
            acc_p += recall_accuracy(pred_p, actual)
            n += 1
            for e in actual:
                hist[tgt, e] = 1.0
    assert n > 0
    assert acc_p / n >= acc_g / n - 1e-9, (acc_p / n, acc_g / n)


def test_engine_routing_is_deterministic(pipeline):
    cfg, eng, trace, log, forest = pipeline
    toks = np.random.default_rng(5).integers(0, cfg.vocab_size, (2, 10))
    out1, tr1, _ = eng.generate(toks, n_steps=4)
    out2, tr2, _ = eng.generate(toks, n_steps=4)
    assert np.array_equal(out1, out2)
    for a, b in zip(tr1.steps, tr2.steps):
        for x, y in zip(a.assignments, b.assignments):
            assert np.array_equal(x, y)


def test_blocking_swapout_hurts(pipeline):
    """§3.4: swap-out contention (baseline) vs prioritized miss handling."""
    cfg, eng, trace, log, forest = pipeline
    hw = PLATFORMS["rtx4090"]
    with_block = simulate(trace, _spec(trace, 0.5), hw,
                          ablation("block", blocking_swap_out=True),
                          forest=forest)
    without = simulate(trace, _spec(trace, 0.5), hw, expertflow(),
                       forest=forest)
    assert without.total_stall_s <= with_block.total_stall_s + 1e-9
