"""TransferLink invariants, seeded-fuzz edition.

Mirrors the hypothesis properties in `test_properties.py` but runs without
hypothesis installed: each test sweeps many seeded random workloads through
the link and checks the §3.3.2/§3.4 queueing invariants —
  1. completion times are monotone in submit order within a priority class;
  2. promote() never reorders in-flight (started/completed) work;
  3. finish() and drain_until() agree on done_t;
  4. bytes_moved equals the sum of completed transfer sizes.
"""
import numpy as np
import pytest

from repro.core.prefetcher import (PRIO_MISS, PRIO_PREFETCH, PRIO_WRITEBACK,
                                   Prefetcher, Transfer, TransferLink)

SEEDS = range(25)


def random_transfers(rng, n=None, prios=(0, 1, 2)):
    n = n if n is not None else int(rng.integers(3, 40))
    return [((int(rng.choice(prios)), i),
             int(rng.choice(prios)),
             float(rng.uniform(0.0, 5.0)),
             float(rng.uniform(1e5, 1e8)))
            for i in range(n)]


def submit_all(link, items):
    for key, prio, t, nbytes in items:
        link.submit(Transfer((0, key[1]), nbytes, prio, t))


@pytest.mark.parametrize("seed", SEEDS)
def test_completion_monotone_within_priority_class(seed):
    rng = np.random.default_rng(seed)
    items = random_transfers(rng)
    link = TransferLink(bandwidth=1e9)
    submit_all(link, items)
    # interleave partial drains to exercise the stop-at-t path
    for t in sorted(rng.uniform(0.0, 10.0, size=3)):
        link.drain_until(t)
    link.drain_until(1e12)
    assert len(link.completed) == len(items)
    by_prio = {}
    for _, prio, _, _ in items:
        by_prio.setdefault(prio, [])
    for tr in link.completed:
        by_prio.setdefault(tr.priority, [])
    for prio in (PRIO_MISS, PRIO_PREFETCH, PRIO_WRITEBACK):
        done = sorted((tr for tr in link.completed if tr.priority == prio),
                      key=lambda tr: tr.key[1])    # submit order
        for a, b in zip(done, done[1:]):
            assert b.done_t >= a.done_t - 1e-9
        # and each transfer starts no earlier than its issue time
        for tr in done:
            assert tr.start_t >= tr.issue_t - 1e-12


@pytest.mark.parametrize("seed", SEEDS)
def test_promote_never_reorders_in_flight_work(seed):
    rng = np.random.default_rng(1000 + seed)
    items = random_transfers(rng, prios=(1, 2))
    link = TransferLink(bandwidth=1e9)
    submit_all(link, items)
    link.drain_until(float(rng.uniform(0.0, 0.05)))
    before = {tr.key: tr.done_t for tr in link.completed}
    promoted = (0, int(rng.integers(len(items))))
    link.promote(promoted)
    link.drain_until(1e12)
    after = {tr.key: tr.done_t for tr in link.completed}
    # started/completed transfers keep their completion times
    for k, t in before.items():
        assert after[k] == t
    # relative FIFO order among non-promoted peers of each class holds
    for prio in (PRIO_PREFETCH, PRIO_WRITEBACK):
        done = sorted((tr for tr in link.completed
                       if tr.priority == prio and tr.key != promoted),
                      key=lambda tr: tr.key[1])
        for a, b in zip(done, done[1:]):
            assert b.done_t >= a.done_t - 1e-9


@pytest.mark.parametrize("seed", SEEDS)
def test_finish_agrees_with_drain_until(seed):
    rng = np.random.default_rng(2000 + seed)
    items = random_transfers(rng)
    la, lb = TransferLink(1e9), TransferLink(1e9)
    submit_all(la, items)
    submit_all(lb, items)
    key = (0, int(rng.integers(len(items))))
    t_finish = la.finish(key, 0.0)
    lb.drain_until(1e12)
    t_drain = next(tr.done_t for tr in lb.completed if tr.key == key)
    assert t_finish == t_drain


@pytest.mark.parametrize("seed", SEEDS)
def test_bytes_moved_equals_completed_sizes(seed):
    rng = np.random.default_rng(3000 + seed)
    items = random_transfers(rng)
    link = TransferLink(bandwidth=1e9)
    submit_all(link, items)
    for t in sorted(rng.uniform(0.0, 10.0, size=4)):
        link.drain_until(t)
        assert link.bytes_moved == pytest.approx(
            sum(tr.nbytes for tr in link.completed))
    link.drain_until(1e12)
    assert link.bytes_moved == pytest.approx(
        sum(tr.nbytes for tr in link.completed))
    assert len(link.completed) == len(items)


def test_prefetcher_observed_bandwidth_matches_link():
    """Prefetcher-level: bytes accounting composes through demand()."""
    link = TransferLink(1e8)
    pf = Prefetcher(link, expert_bytes=1e6)
    for i in range(5):
        pf.prefetch((0, i), 0.0)
    done_t = pf.demand((0, 7), 0.0)       # cold miss jumps the queue... of
    assert done_t > 0.0                   # ...queued (not started) work
    link.drain_until(1e12)
    assert link.bytes_moved == pytest.approx(6e6)
