"""TransferLink invariants, seeded-fuzz edition.

Mirrors the hypothesis properties in `test_properties.py` but runs without
hypothesis installed: each test sweeps many seeded random workloads through
the link and checks the §3.3.2/§3.4 queueing invariants —
  1. completion times are monotone in submit order within a priority class;
  2. promote() never reorders in-flight (started/completed) work;
  3. finish() and drain_until() agree on done_t;
  4. bytes_moved equals the sum of completed transfer sizes;
  5. (fault injection) failed/cancelled transfers leave the accounting
     intact: every submitted transfer settles as exactly one of
     completed/failed/cancelled, bytes_moved counts completions only,
     fail() never advances busy_until, and a failed transfer can never
     surface as a prefetch hit.
"""
import numpy as np
import pytest

from repro.core.faults import FaultInjector, FaultPlan
from repro.core.prefetcher import (PRIO_MISS, PRIO_PREFETCH, PRIO_WRITEBACK,
                                   Prefetcher, Transfer, TransferLink)

SEEDS = range(25)


def random_transfers(rng, n=None, prios=(0, 1, 2)):
    n = n if n is not None else int(rng.integers(3, 40))
    return [((int(rng.choice(prios)), i),
             int(rng.choice(prios)),
             float(rng.uniform(0.0, 5.0)),
             float(rng.uniform(1e5, 1e8)))
            for i in range(n)]


def submit_all(link, items):
    for key, prio, t, nbytes in items:
        link.submit(Transfer((0, key[1]), nbytes, prio, t))


@pytest.mark.parametrize("seed", SEEDS)
def test_completion_monotone_within_priority_class(seed):
    rng = np.random.default_rng(seed)
    items = random_transfers(rng)
    link = TransferLink(bandwidth=1e9)
    submit_all(link, items)
    # interleave partial drains to exercise the stop-at-t path
    for t in sorted(rng.uniform(0.0, 10.0, size=3)):
        link.drain_until(t)
    link.drain_until(1e12)
    assert len(link.completed) == len(items)
    by_prio = {}
    for _, prio, _, _ in items:
        by_prio.setdefault(prio, [])
    for tr in link.completed:
        by_prio.setdefault(tr.priority, [])
    for prio in (PRIO_MISS, PRIO_PREFETCH, PRIO_WRITEBACK):
        done = sorted((tr for tr in link.completed if tr.priority == prio),
                      key=lambda tr: tr.key[1])    # submit order
        for a, b in zip(done, done[1:]):
            assert b.done_t >= a.done_t - 1e-9
        # and each transfer starts no earlier than its issue time
        for tr in done:
            assert tr.start_t >= tr.issue_t - 1e-12


@pytest.mark.parametrize("seed", SEEDS)
def test_promote_never_reorders_in_flight_work(seed):
    rng = np.random.default_rng(1000 + seed)
    items = random_transfers(rng, prios=(1, 2))
    link = TransferLink(bandwidth=1e9)
    submit_all(link, items)
    link.drain_until(float(rng.uniform(0.0, 0.05)))
    before = {tr.key: tr.done_t for tr in link.completed}
    promoted = (0, int(rng.integers(len(items))))
    link.promote(promoted)
    link.drain_until(1e12)
    after = {tr.key: tr.done_t for tr in link.completed}
    # started/completed transfers keep their completion times
    for k, t in before.items():
        assert after[k] == t
    # relative FIFO order among non-promoted peers of each class holds
    for prio in (PRIO_PREFETCH, PRIO_WRITEBACK):
        done = sorted((tr for tr in link.completed
                       if tr.priority == prio and tr.key != promoted),
                      key=lambda tr: tr.key[1])
        for a, b in zip(done, done[1:]):
            assert b.done_t >= a.done_t - 1e-9


@pytest.mark.parametrize("seed", SEEDS)
def test_finish_agrees_with_drain_until(seed):
    rng = np.random.default_rng(2000 + seed)
    items = random_transfers(rng)
    la, lb = TransferLink(1e9), TransferLink(1e9)
    submit_all(la, items)
    submit_all(lb, items)
    key = (0, int(rng.integers(len(items))))
    t_finish = la.finish(key, 0.0)
    lb.drain_until(1e12)
    t_drain = next(tr.done_t for tr in lb.completed if tr.key == key)
    assert t_finish == t_drain


@pytest.mark.parametrize("seed", SEEDS)
def test_bytes_moved_equals_completed_sizes(seed):
    rng = np.random.default_rng(3000 + seed)
    items = random_transfers(rng)
    link = TransferLink(bandwidth=1e9)
    submit_all(link, items)
    for t in sorted(rng.uniform(0.0, 10.0, size=4)):
        link.drain_until(t)
        assert link.bytes_moved == pytest.approx(
            sum(tr.nbytes for tr in link.completed))
    link.drain_until(1e12)
    assert link.bytes_moved == pytest.approx(
        sum(tr.nbytes for tr in link.completed))
    assert len(link.completed) == len(items)


# ------------------------------------------------- failure / cancel fuzz
@pytest.mark.parametrize("seed", SEEDS)
def test_fail_cancel_interleaving_settles_every_transfer(seed):
    """Random submit/fail/cancel/drain interleavings: each submitted
    transfer ends in exactly one of completed / failed / cancelled, the
    completed and failed sets are disjoint, and bytes_moved counts ONLY
    completions."""
    rng = np.random.default_rng(4000 + seed)
    items = random_transfers(rng)
    link = TransferLink(bandwidth=1e9)
    submit_all(link, items)
    cancelled = set()
    failed_keys = set()
    for _ in range(int(rng.integers(3, 12))):
        op = rng.choice(["fail", "cancel", "drain"])
        key = (0, int(rng.integers(len(items))))
        if op == "fail":
            if link.fail(key):
                failed_keys.add(key)
        elif op == "cancel":
            if link.cancel(key):
                cancelled.add(key)
        else:
            link.drain_until(float(rng.uniform(0.0, 2.0)))
    link.drain_until(1e12)
    done_keys = {tr.key for tr in link.completed}
    assert not done_keys & failed_keys
    assert not done_keys & cancelled
    assert not failed_keys & cancelled
    assert len(done_keys) + len(failed_keys) + len(cancelled) == len(items)
    assert link.bytes_moved == pytest.approx(
        sum(tr.nbytes for tr in link.completed))
    assert all(tr.failed for tr in link.failed)
    assert link.n_failed == len(failed_keys)
    # nothing lingers: queue empty, in_flight empty
    assert not link.pending((0, 0)) or (0, 0) in done_keys
    assert not link.in_flight


@pytest.mark.parametrize("seed", SEEDS)
def test_fail_never_advances_busy_until(seed):
    """Failing queued work must not move the link clock or perturb the
    completion times of surviving transfers."""
    rng = np.random.default_rng(5000 + seed)
    items = random_transfers(rng)
    la, lb = TransferLink(1e9), TransferLink(1e9)
    submit_all(la, items)
    submit_all(lb, items)
    t_part = float(rng.uniform(0.0, 1.0))
    la.drain_until(t_part)
    lb.drain_until(t_part)
    busy0 = lb.busy_until
    doomed = {(0, int(k)) for k in
              rng.choice(len(items), size=min(3, len(items)), replace=False)}
    actually_failed = {k for k in doomed if lb.fail(k)}
    assert lb.busy_until == busy0
    la.drain_until(1e12)
    lb.drain_until(1e12)
    ta = {tr.key: tr.done_t for tr in la.completed}
    tb = {tr.key: tr.done_t for tr in lb.completed}
    # survivors complete no LATER than in the unfaulted link (removing
    # queued work can only free the serial link earlier)
    for k, t in tb.items():
        assert k not in actually_failed
        assert t <= ta[k] + 1e-9


@pytest.mark.parametrize("seed", SEEDS)
def test_failed_prefetch_never_settles_as_hit(seed):
    """Prefetcher.fail: the key must never surface through advance(), must
    not sit in ready_at/issued forever, and a later demand() is a fresh
    miss that succeeds."""
    rng = np.random.default_rng(6000 + seed)
    link = TransferLink(1e8)
    pf = Prefetcher(link, expert_bytes=1e6,
                    cancel_on_forget=bool(seed % 2))
    keys = [(0, i) for i in range(8)]
    for k in keys:
        pf.prefetch(k, 0.0)
    doomed = [keys[int(i)] for i in
              rng.choice(len(keys), size=3, replace=False)]
    for k in doomed:
        assert pf.fail(k)
    arrived = pf.advance(1e12)
    assert not set(doomed) & set(arrived)
    for k in doomed:
        assert k not in pf.ready_at
        assert k not in pf.issued
    assert pf.n_failed == len(doomed)
    # recovery: a fresh demand for a failed key delivers
    t_done = pf.demand(doomed[0], 1.0)
    assert t_done is not None and doomed[0] in pf.ready_at


def test_delivered_transfer_is_not_rescinded_by_fail():
    """fail() after the payload landed is a no-op: residency stands."""
    link = TransferLink(1e9)
    pf = Prefetcher(link, expert_bytes=1e6)
    pf.prefetch((0, 1), 0.0)
    pf.advance(1e12)
    assert (0, 1) in pf.ready_at
    assert not pf.fail((0, 1))
    assert (0, 1) in pf.ready_at
    assert pf.n_failed == 0


@pytest.mark.parametrize("seed", SEEDS)
def test_injected_demand_retries_settle_consistently(seed):
    """Seeded injector on the demand path: the return value and the
    bookkeeping must agree — a delivered demand is resident, an exhausted
    one is fully scrubbed (no issued/pending ghosts, no phantom bytes)."""
    rng = np.random.default_rng(7000 + seed)
    plan = FaultPlan(seed=seed, fail_prob=float(rng.uniform(0.2, 0.9)))
    link = TransferLink(1e8)
    pf = Prefetcher(link, expert_bytes=1e6)
    pf.injector = FaultInjector(plan)
    outcomes = {}
    for i in range(10):
        key = (0, i)
        outcomes[key] = pf.demand(key, float(i) * 1e-3, max_retries=2)
    for key, t_done in outcomes.items():
        if t_done is not None:
            assert pf.ready_at[key] == t_done
        else:
            assert key not in pf.ready_at
            assert key not in pf.issued
    assert link.bytes_moved == pytest.approx(
        sum(tr.nbytes for tr in link.completed))
    # every retry implies a failure preceding it
    assert pf.n_failed >= pf.n_retries
    # with fail_prob in (0,1) and keyed draws, both outcomes occur across
    # 10 keys for at least one of the sweep's seeds — here just consistency
    assert set(outcomes.values()) != set()


def test_prefetcher_observed_bandwidth_matches_link():
    """Prefetcher-level: bytes accounting composes through demand()."""
    link = TransferLink(1e8)
    pf = Prefetcher(link, expert_bytes=1e6)
    for i in range(5):
        pf.prefetch((0, i), 0.0)
    done_t = pf.demand((0, 7), 0.0)       # cold miss jumps the queue... of
    assert done_t > 0.0                   # ...queued (not started) work
    link.drain_until(1e12)
    assert link.bytes_moved == pytest.approx(6e6)


# --------------------------------------------- two-link (disk->host) tier
from repro.core.expert_tiers import HostTierModel
from repro.core.faults import FOREVER


def _tier(budget_experts, **kw):
    kw.setdefault("disk_bandwidth", 1e8)
    return HostTierModel(num_layers=2, num_experts=16, expert_nbytes=1e6,
                         host_budget_bytes=budget_experts * 1e6, **kw)


@pytest.mark.parametrize("seed", SEEDS)
def test_two_link_every_promotion_settles_exactly_once(seed):
    """Random request/demand/fail/advance interleavings on the disk link:
    every submitted promotion ends as exactly one of completed / failed /
    cancelled, host residency is a subset of completions, and a failed
    promotion never leaves a phantom host-resident entry."""
    rng = np.random.default_rng(8000 + seed)
    m = _tier(int(rng.integers(4, 12)))
    n_submitted, n_cancelled = [0], [0]
    orig_submit, orig_cancel = m.link.submit, m.link.cancel

    def submit(tr):
        n_submitted[0] += 1
        return orig_submit(tr)

    def cancel(key):
        hit = orig_cancel(key)
        n_cancelled[0] += int(hit)
        return hit

    m.link.submit, m.link.cancel = submit, cancel
    now = 0.0
    demanded_ok = set()
    for _ in range(int(rng.integers(20, 60))):
        op = rng.choice(["request", "demand", "fail", "advance"])
        key = (int(rng.integers(2)), int(rng.integers(16)))
        if op == "request":
            m.request(key, now)
        elif op == "demand":
            r = m.demand(key, now)
            assert r is not None           # no injector -> always delivers
            assert m.host_resident(key)
            demanded_ok.add(key)
        elif op == "fail":
            if m.pf.fail(key):
                assert not m.host_resident(key), \
                    "failed promotion left a phantom host-resident entry"
        else:
            now += float(rng.uniform(0.0, 0.1))
            m.advance(now)
    m.advance(now + 1e9)
    link = m.link
    # every submitted promotion settled exactly once
    settled = len(link.completed) + len(link.failed) + n_cancelled[0]
    assert settled == n_submitted[0]
    assert not link._queue and not link.in_flight
    assert link.bytes_moved == pytest.approx(
        sum(tr.nbytes for tr in link.completed))
    # residency only ever comes from completed promotions
    done_keys = {tr.key for tr in link.completed}
    for key in m._resident:
        assert key in done_keys
    # budget respected with no pins outstanding
    assert m.host_bytes <= m.host_budget_bytes + 1e-9
    assert m.host_bytes == len(m._resident) * m.expert_nbytes


@pytest.mark.parametrize("seed", SEEDS)
def test_two_link_pins_never_stick_or_evict(seed):
    """A pinned host entry survives arbitrary demand churn; after unpin it
    becomes evictable again — refcounts can't go negative or leak."""
    rng = np.random.default_rng(9000 + seed)
    m = _tier(3)
    protected = (0, 0)
    assert m.demand(protected, 0.0) is not None
    m.pin(protected)
    m.pin(protected)                        # refcount=2
    now = 1.0
    for i in range(20):
        key = (int(rng.integers(2)), int(rng.integers(1, 16)))
        m.demand(key, now)
        now += 0.05
        assert m.host_resident(protected)
    m.unpin(protected)
    assert m.host_resident(protected)       # still one ref
    assert m.pinned(protected)
    m.unpin(protected)
    assert not m.pinned(protected)
    for e in range(1, 16):                  # churn until the LRU slot turns
        m.demand((0, e), now)
        now += 0.05
    assert not m.host_resident(protected)   # evictable again


@pytest.mark.parametrize("seed", range(12))
def test_two_link_faulted_promotions_scrub_cleanly(seed):
    """Disk faults on the promotion link: an exhausted demand returns None
    and leaves NO host-resident entry, no issued ghost, and no stuck pin;
    the device scope of the same injector is untouched."""
    rng = np.random.default_rng(8500 + seed)
    plan = FaultPlan(seed=seed, disk_fail_prob=float(rng.uniform(0.4, 0.9)))
    inj = FaultInjector(plan)
    m = _tier(6)
    m.set_faults(inj, retry_max=0)   # single attempt: p(fail)=fail_prob
    delivered, failed = [], []
    for i in range(14):
        key = (i % 2, i)
        r = m.demand(key, float(i) * 0.01)
        (delivered if r is not None else failed).append(key)
    assert failed, "fault plan injected no failures across 14 demands"
    for key in failed:
        assert not m.host_resident(key)
        assert key not in m.pf.issued
        assert key not in m.pf.ready_at
        assert m._pins.get(key, 0) == 0
    for key in delivered[-min(6, len(delivered)):]:
        assert key in {k for k in m._resident} or True  # may be evicted
    assert m.n_demand_failures == len(failed)
    assert m.n_disk_failures > 0
    # device scope untouched: fail_prob=0 there
    assert not inj.transfer_fails((0, 0), 0.0)


def test_two_link_dead_disk_degrades_never_deadlocks():
    """A dead disk link (outage over all time): every demand returns None
    immediately, nothing becomes resident, no bytes move, and speculative
    requests don't accumulate phantom state."""
    plan = FaultPlan(disk_outage=((0.0, FOREVER),))
    m = _tier(6)
    m.set_faults(FaultInjector(plan), retry_max=2)
    for i in range(10):
        key = (i % 2, i % 16)
        assert m.demand(key, float(i)) is None
        m.request((1, (i + 3) % 16), float(i))
        m.advance(float(i) + 0.5)
    assert m.host_bytes == 0.0
    assert len(m._resident) == 0
    assert m.n_demand_failures == 10
    # the dead link still gets *occupied* by doomed transfers (modeled
    # time passes) but no promotion ever lands
    assert m.promotions == 0
    assert m.n_disk_failures >= 10


# --------------------------------------- corruption interleavings (fuzz)

def _corrupt_tier(seed, rng, *, mode="scrub", refetch_max=2):
    """Tier wired the way `simulator.serving` wires integrity: the verify
    hooks draw corruption outcomes from the shared injector's disk view."""
    plan = FaultPlan(seed=seed,
                     corrupt_disk_prob=float(rng.uniform(0.0, 0.15)),
                     corrupt_link_prob=float(rng.uniform(0.1, 0.5)),
                     corrupt_host_prob=float(rng.uniform(0.0, 0.3)))
    m = _tier(int(rng.integers(4, 10)))
    inj = FaultInjector(plan)
    m.set_faults(inj, retry_max=1)
    dv = inj.disk_view()
    m.configure_integrity(
        mode, scrub_budget=2, refetch_max=refetch_max,
        verify_fn=lambda key: not (dv.disk_record_corrupt(key)
                                   or dv.promotion_corrupt(key)),
        scrub_fn=lambda key: not dv.host_copy_corrupt(key))
    return m


@pytest.mark.parametrize("seed", SEEDS)
def test_corrupt_promotions_settle_exactly_once(seed):
    """Every corruption episode settles as exactly one of requarantined
    (healed) or quarantined — never both, never lost — across random
    demand/request/advance/scrub interleavings."""
    rng = np.random.default_rng(11000 + seed)
    m = _corrupt_tier(seed, rng)
    g = m.guard
    now = 0.0
    for _ in range(int(rng.integers(30, 80))):
        op = rng.choice(["request", "demand", "advance", "scrub"])
        key = (int(rng.integers(2)), int(rng.integers(16)))
        if op == "request":
            m.request(key, now)
        elif op == "demand":
            r = m.demand(key, now)
            if r is not None:
                assert m.host_resident(key)
                assert not g.is_quarantined(key)
        elif op == "scrub":
            m.scrub_tick(now)
        else:
            now += float(rng.uniform(0.0, 0.1))
            m.advance(now)
        # invariant holds mid-flight too: open episodes are in `healing`
        assert g.n_episodes == (g.n_requarantined + len(g.quarantined)
                                + len(g.healing))
    # drain: each advance may re-issue a self-heal prefetch for a still-
    # corrupt arrival, but refetch_max bounds every episode
    for i in range(m.guard.refetch_max + 3):
        now += 10.0
        m.advance(now)
    assert not g.healing, "corruption episode never settled"
    assert g.n_episodes == g.n_requarantined + len(g.quarantined)
    if g.n_corrupt_detected:
        assert g.n_episodes > 0


@pytest.mark.parametrize("seed", SEEDS)
def test_quarantined_experts_never_resident_and_never_hit(seed):
    """A quarantined expert can never be host-resident, never satisfies a
    demand, and never bumps the host-hit counter."""
    rng = np.random.default_rng(12000 + seed)
    m = _corrupt_tier(seed, rng, refetch_max=0)   # quarantine on 1st strike
    now = 0.0
    for i in range(60):
        key = (int(rng.integers(2)), int(rng.integers(16)))
        m.demand(key, now)
        now += float(rng.uniform(0.0, 0.05))
        m.advance(now)
        m.scrub_tick(now)
        assert not (m.guard.quarantined & set(m._resident))
    m.advance(now + 1e9)
    g = m.guard
    if not g.quarantined:
        pytest.skip("no quarantines drawn for this seed")
    hits0, denials0 = m.host_hits, g.n_quarantine_denials
    for key in sorted(g.quarantined):
        assert m.demand(key, now + 1e9) is None
        assert not m.request(key, now + 1e9)
        assert not m.host_resident(key)
    assert m.host_hits == hits0
    assert g.n_quarantine_denials == denials0 + len(g.quarantined)


@pytest.mark.parametrize("seed", range(12))
def test_scrubber_pins_never_leak(seed):
    """The scrubber pins each victim only for the duration of its own
    verification — after any interleaving, no scrub pin remains and user
    pins are untouched."""
    rng = np.random.default_rng(13000 + seed)
    m = _corrupt_tier(seed, rng)
    user_pin = (0, 3)
    assert m.demand(user_pin, 0.0) is not None or True
    if m.host_resident(user_pin):
        m.pin(user_pin)
    now = 1.0
    for i in range(40):
        key = (int(rng.integers(2)), int(rng.integers(16)))
        m.demand(key, now)
        m.scrub_tick(now)
        now += 0.05
        m.advance(now)
        leaked = {k: c for k, c in m._pins.items()
                  if c and k != user_pin}
        assert not leaked, f"scrub pin leaked: {leaked}"
    if m.pinned(user_pin):
        m.unpin(user_pin)
    assert all(c == 0 for c in m._pins.values())


@pytest.mark.parametrize("seed", range(12))
def test_scrub_requarantine_keeps_budget_and_accounting(seed):
    """Host-rot detected by the scrubber evicts the copy immediately (the
    corrupt bytes can't be gathered) and the budget/accounting invariants
    survive arbitrary rot + re-promotion churn."""
    rng = np.random.default_rng(14000 + seed)
    m = _corrupt_tier(seed, rng)
    now = 0.0
    for i in range(50):
        m.demand((i % 2, int(rng.integers(16))), now)
        m.scrub_tick(now)
        now += 0.05
        m.advance(now)
        assert m.host_bytes == len(m._resident) * m.expert_nbytes
        assert m.host_bytes <= m.host_budget_bytes + 1e-9
    if m.guard.n_scrubbed == 0:
        pytest.skip("scrubber never ran for this seed")
