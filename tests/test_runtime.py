"""Engine + slot-buffer + batching + checkpoint integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, load_checkpoint, save_checkpoint
from repro.configs.registry import get_smoke_config
from repro.core import FeatureSpec, ForestPredictor
from repro.runtime.batching import ContinuousBatcher
from repro.runtime.engine import Engine, SlotBufferEngine, _all_specs, \
    _layer_params
from repro.runtime.request import Request
from repro.models.transformer import layer_forward


@pytest.fixture(scope="module")
def engine():
    return Engine(get_smoke_config("qwen1.5-moe-a2.7b"), max_seq=96)


@pytest.mark.slow
def test_engine_generates_and_collects_traces(engine):
    toks = np.random.default_rng(0).integers(
        0, engine.cfg.vocab_size, (2, 12))
    out, trace, log = engine.generate(toks, n_steps=6)
    assert out.shape == (2, 6)
    assert len(trace.steps) == 6
    L = len(engine.moe_layer_ids)
    assert trace.num_moe_layers == L
    for st in trace.steps:
        assert len(st.assignments) == L
        assert st.hidden_pooled.shape == (L, engine.cfg.d_model)
    assert len(log.samples) == 6 * L


@pytest.mark.slow
def test_engine_trace_feeds_predictor(engine):
    toks = np.random.default_rng(1).integers(
        0, engine.cfg.vocab_size, (2, 12))
    _, trace, log = engine.generate(toks, n_steps=8)
    spec = FeatureSpec(engine.cfg.vocab_size, 8, trace.num_moe_layers,
                       trace.num_experts, include_pregate=True)
    pred = ForestPredictor(spec)
    mse = pred.fit(log)
    assert np.isfinite(mse) and mse < 0.5


def _eager_unrolled(model, params, cfg, toks):
    """Fully-resident eager reference (op-by-op, no jit)."""
    x = model.embed(params, toks)
    B, T = toks.shape
    positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
    for i, spec in enumerate(_all_specs(model)):
        x = layer_forward(_layer_params(model, params, i), cfg, spec, x,
                          positions)
    return x


@pytest.mark.slow
def test_slot_buffer_engine_exact_vs_reference():
    """The fused slot path must be BIT-exact versus the fully-resident model
    computed through the same jitted functions (identity slot table over the
    raw stacked weights) — the slot mechanism (indirection, batched swaps,
    prefetch) adds zero numerical difference. The eager unrolled model
    anchors it within bf16 jit-vs-eager rounding."""
    cfg = get_smoke_config("olmoe-1b-7b")
    eng = Engine(cfg, max_seq=64)
    toks = jnp.asarray(np.random.default_rng(2).integers(
        0, cfg.vocab_size, (2, 10)), jnp.int32)
    sb = SlotBufferEngine(cfg, eng.params, eng.model,
                          n_slots_per_layer=cfg.moe.num_experts)
    x_sb = sb.forward(toks)
    x_ref = sb.reference_forward(toks)
    assert float(jnp.max(jnp.abs(x_sb - x_ref))) == 0.0
    assert sb.swap_count > 0
    x_eager = _eager_unrolled(eng.model, eng.params, cfg, toks)
    np.testing.assert_allclose(
        np.asarray(x_sb, np.float32), np.asarray(x_eager, np.float32),
        rtol=5e-2, atol=5e-2)


@pytest.mark.slow
def test_slot_buffer_legacy_exact_vs_unrolled():
    """The pre-fused path keeps the original guarantee verbatim: eager
    slot-buffer execution is bit-exact versus the eager unrolled model."""
    cfg = get_smoke_config("olmoe-1b-7b")
    eng = Engine(cfg, max_seq=64)
    toks = jnp.asarray(np.random.default_rng(2).integers(
        0, cfg.vocab_size, (2, 10)), jnp.int32)
    sb = SlotBufferEngine(cfg, eng.params, eng.model,
                          n_slots_per_layer=cfg.moe.num_experts, fused=False)
    x_sb = sb.forward(toks)
    x = _eager_unrolled(eng.model, eng.params, cfg, toks)
    assert float(jnp.max(jnp.abs(x_sb - x))) == 0.0
    assert sb.swap_count > 0


@pytest.mark.slow
def test_slot_buffer_bit_exact_across_evictions():
    """Regression: with fewer slots than experts (forced swap-in/release
    churn), repeated forwards must stay bit-exact versus the fully-resident
    reference — eviction must never corrupt the indirection or weights."""
    cfg = get_smoke_config("olmoe-1b-7b")
    eng = Engine(cfg, max_seq=64)
    sb = SlotBufferEngine(cfg, eng.params, eng.model,
                          n_slots_per_layer=cfg.moe.num_experts // 2)
    rng = np.random.default_rng(11)
    for trial in range(3):
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 6)),
                           jnp.int32)
        x_sb = sb.forward(toks)
        x = sb.reference_forward(toks)
        assert float(jnp.max(jnp.abs(x_sb - x))) == 0.0, \
            f"divergence on forward #{trial}"
    # the tight buffer must actually have churned
    assert sb.cache.stats.evictions > 0
    assert sb.table.n_resident <= sb.n_slots


@pytest.mark.slow
def test_slot_buffer_fused_batches_swaps_and_prefetches():
    """The hot path must issue BATCHED swaps (far fewer device swap calls
    than experts moved), pull only the small mask to host, and prefetch the
    next layer's experts ahead of demand."""
    cfg = get_smoke_config("olmoe-1b-7b")
    eng = Engine(cfg, max_seq=64)
    toks = jnp.asarray(np.random.default_rng(5).integers(
        0, cfg.vocab_size, (2, 12)), jnp.int32)
    sb = SlotBufferEngine(cfg, eng.params, eng.model,
                          n_slots_per_layer=cfg.moe.num_experts)
    sb.forward(toks)
    st = sb.stats
    n_moe = len(sb.moe_layer_ids)
    # at most one demand + one prefetch swap dispatch per MoE layer
    assert st.swap_calls <= 2 * n_moe
    assert st.swap_experts >= st.swap_calls  # batching actually batched
    assert st.prefetched > 0
    assert st.prefetch_hits > 0              # predictions actually landed
    assert st.host_syncs == n_moe            # one mask pull per MoE layer
    # transfers were accounted through the paper's link model
    assert sb.link.bytes_moved > 0


@pytest.mark.slow
def test_prefetch_never_self_evicts_into_duplicate_slots():
    """Regression: with one free slot and an empty low tier, prefetching
    two experts must NOT let the second insert evict the first — that would
    put two different payloads at the same slot index inside one batched
    swap (nondeterministic scatter) and silently desync table and buffer."""
    cfg = get_smoke_config("olmoe-1b-7b")
    eng = Engine(cfg, max_seq=64)
    E = cfg.moe.num_experts
    # capacity E+1 total: demand-fill layer 0 completely -> 1 free slot,
    # low tier empty (demand inserts go high)
    sb = SlotBufferEngine(cfg, eng.params, eng.model, n_slots_per_layer=1)
    sb.n_slots = E + 1
    sb.table = type(sb.table)(len(sb.moe_layer_ids), E, sb.n_slots)
    sb.cache.capacity = E + 1
    from repro.core.expert_buffer import make_buffer
    sb.buffer = make_buffer(cfg, sb.n_slots)
    sb.ensure_resident(0, list(range(E)))
    assert sb.cache.free_slots == 1 and not sb.cache.low
    issued = sb.prefetch_layer(1, [0, 1])
    assert issued == 1                        # second fill refused, not
    s0 = sb.table.lookup(1, 0)                # stacked onto the first
    assert s0 >= 0 and sb.table.lookup(1, 1) == -1
    # table and buffer agree: the issued expert's weights are in its slot
    wg_expected = sb.store.gather(1, [0])[0]
    np.testing.assert_array_equal(
        np.asarray(sb.buffer["w_gate"][s0], np.float32),
        np.asarray(wg_expected[0], np.float32))


@pytest.mark.slow
def test_slot_buffer_kernel_path_matches_einsum():
    """use_kernel=True routes the FFN through the Pallas slot-indirect
    kernel (interpret mode on CPU): bit-exact vs its own reference, and
    within bf16 tolerance of the einsum path."""
    cfg = get_smoke_config("olmoe-1b-7b")
    eng = Engine(cfg, max_seq=64)
    toks = jnp.asarray(np.random.default_rng(7).integers(
        0, cfg.vocab_size, (2, 8)), jnp.int32)
    sb_e = SlotBufferEngine(cfg, eng.params, eng.model,
                            n_slots_per_layer=cfg.moe.num_experts)
    sb_k = SlotBufferEngine(cfg, eng.params, eng.model,
                            n_slots_per_layer=cfg.moe.num_experts,
                            use_kernel=True)
    x_e = sb_e.forward(toks)
    x_k = sb_k.forward(toks)
    assert float(jnp.max(jnp.abs(x_k - sb_k.reference_forward(toks)))) == 0.0
    np.testing.assert_allclose(np.asarray(x_k, np.float32),
                               np.asarray(x_e, np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.slow
def test_slot_buffer_bounded_capacity_evicts_and_still_works():
    cfg = get_smoke_config("olmoe-1b-7b")
    eng = Engine(cfg, max_seq=64)
    # only half the experts fit per layer
    sb = SlotBufferEngine(cfg, eng.params, eng.model,
                          n_slots_per_layer=cfg.moe.num_experts // 2)
    toks = jnp.asarray(np.random.default_rng(3).integers(
        0, cfg.vocab_size, (1, 6)), jnp.int32)
    x1 = sb.forward(toks)
    swaps_first = sb.swap_count
    x2 = sb.forward(toks)
    assert jnp.isfinite(x1).all() and jnp.isfinite(x2).all()
    # deterministic routing -> second pass hits cached experts more
    assert sb.swap_count - swaps_first <= swaps_first


def test_continuous_batcher_slots_and_completion():
    b = ContinuousBatcher(max_batch=2)
    reqs = [Request(np.arange(4), max_new_tokens=2) for _ in range(3)]
    for r in reqs:
        b.submit(r)
    admitted = b.admit()
    assert len(admitted) == 2 and b.waiting
    finished = b.step({0: 7, 1: 8})
    assert not finished
    finished = b.step({0: 9, 1: 10})
    assert len(finished) == 2
    admitted = b.admit()
    assert len(admitted) == 1 and admitted[0].slot in (0, 1)
    b.step({admitted[0].slot: 1})
    b.step({admitted[0].slot: 2})
    assert not b.has_work
    assert b.stats.completed == 3


def test_continuous_batcher_arrival_gated_admission_and_release():
    b = ContinuousBatcher(max_batch=2)
    early = Request(np.arange(4), max_new_tokens=1)
    late = Request(np.arange(4), max_new_tokens=1)
    early.arrival_s, late.arrival_s = 0.0, 5.0
    b.submit(early)
    b.submit(late)
    # at t=1 only the arrived request is admitted
    admitted = b.admit(now=1.0)
    assert admitted == [early] and len(b.waiting) == 1
    # release frees the slot outside the step() path
    early.output.append(3)
    b.release(early)
    assert early.slot not in b.active and b.stats.completed == 1
    # double-release is a no-op
    b.release(early)
    assert b.stats.completed == 1
    admitted = b.admit(now=6.0)
    assert admitted == [late] and not b.waiting


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.bfloat16),
                  {"c": jnp.zeros((2, 2), jnp.int32)}]}
    save_checkpoint(str(tmp_path / "ck"), tree, step=7)
    restored, step = load_checkpoint(str(tmp_path / "ck"), tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpointer_retention_and_restore(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, every=1)
    state = {"w": jnp.zeros((3,))}
    for s in range(1, 5):
        state = {"w": state["w"] + 1}
        ck.maybe_save(s, state, blocking=True)
    dirs = sorted(p.name for p in tmp_path.iterdir())
    assert dirs == ["step_3", "step_4"]
    restored, step = ck.restore_latest(state)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.full(3, 4.0))
