"""Engine + slot-buffer + batching + checkpoint integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, load_checkpoint, save_checkpoint
from repro.configs.registry import get_smoke_config
from repro.core import FeatureSpec, ForestPredictor
from repro.runtime.batching import ContinuousBatcher
from repro.runtime.engine import Engine, SlotBufferEngine, _all_specs, \
    _layer_params
from repro.runtime.request import Request
from repro.models.transformer import layer_forward


@pytest.fixture(scope="module")
def engine():
    return Engine(get_smoke_config("qwen1.5-moe-a2.7b"), max_seq=96)


@pytest.mark.slow
def test_engine_generates_and_collects_traces(engine):
    toks = np.random.default_rng(0).integers(
        0, engine.cfg.vocab_size, (2, 12))
    out, trace, log = engine.generate(toks, n_steps=6)
    assert out.shape == (2, 6)
    assert len(trace.steps) == 6
    L = len(engine.moe_layer_ids)
    assert trace.num_moe_layers == L
    for st in trace.steps:
        assert len(st.assignments) == L
        assert st.hidden_pooled.shape == (L, engine.cfg.d_model)
    assert len(log.samples) == 6 * L


@pytest.mark.slow
def test_engine_trace_feeds_predictor(engine):
    toks = np.random.default_rng(1).integers(
        0, engine.cfg.vocab_size, (2, 12))
    _, trace, log = engine.generate(toks, n_steps=8)
    spec = FeatureSpec(engine.cfg.vocab_size, 8, trace.num_moe_layers,
                       trace.num_experts, include_pregate=True)
    pred = ForestPredictor(spec)
    mse = pred.fit(log)
    assert np.isfinite(mse) and mse < 0.5


@pytest.mark.slow
def test_slot_buffer_engine_exact_vs_unrolled():
    cfg = get_smoke_config("olmoe-1b-7b")
    eng = Engine(cfg, max_seq=64)
    toks = jnp.asarray(np.random.default_rng(2).integers(
        0, cfg.vocab_size, (2, 10)), jnp.int32)
    sb = SlotBufferEngine(cfg, eng.params, eng.model,
                          n_slots_per_layer=cfg.moe.num_experts)
    x_sb = sb.forward(toks)
    # unrolled reference (same op order as the slot engine)
    model, params = eng.model, eng.params
    x = model.embed(params, toks)
    positions = jnp.broadcast_to(jnp.arange(10)[None, :], (2, 10))
    for i, spec in enumerate(_all_specs(model)):
        x = layer_forward(_layer_params(model, params, i), cfg, spec, x,
                          positions)
    assert float(jnp.max(jnp.abs(x_sb - x))) == 0.0
    assert sb.swap_count > 0


@pytest.mark.slow
def test_slot_buffer_bit_exact_across_evictions():
    """Regression: with fewer slots than experts (forced swap-in/release
    churn), repeated forwards must stay bit-exact versus the fully-resident
    reference — eviction must never corrupt the indirection or weights."""
    cfg = get_smoke_config("olmoe-1b-7b")
    eng = Engine(cfg, max_seq=64)
    sb = SlotBufferEngine(cfg, eng.params, eng.model,
                          n_slots_per_layer=cfg.moe.num_experts // 2)
    model, params = eng.model, eng.params
    rng = np.random.default_rng(11)
    for trial in range(3):
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 6)),
                           jnp.int32)
        x_sb = sb.forward(toks)
        x = model.embed(params, toks)
        positions = jnp.broadcast_to(jnp.arange(6)[None, :], (1, 6))
        for i, spec in enumerate(_all_specs(model)):
            x = layer_forward(_layer_params(model, params, i), cfg, spec, x,
                              positions)
        assert float(jnp.max(jnp.abs(x_sb - x))) == 0.0, \
            f"divergence on forward #{trial}"
    # the tight buffer must actually have churned
    assert sb.cache.stats.evictions > 0
    assert sb.table.n_resident <= sb.n_slots


@pytest.mark.slow
def test_slot_buffer_bounded_capacity_evicts_and_still_works():
    cfg = get_smoke_config("olmoe-1b-7b")
    eng = Engine(cfg, max_seq=64)
    # only half the experts fit per layer
    sb = SlotBufferEngine(cfg, eng.params, eng.model,
                          n_slots_per_layer=cfg.moe.num_experts // 2)
    toks = jnp.asarray(np.random.default_rng(3).integers(
        0, cfg.vocab_size, (1, 6)), jnp.int32)
    x1 = sb.forward(toks)
    swaps_first = sb.swap_count
    x2 = sb.forward(toks)
    assert jnp.isfinite(x1).all() and jnp.isfinite(x2).all()
    # deterministic routing -> second pass hits cached experts more
    assert sb.swap_count - swaps_first <= swaps_first


def test_continuous_batcher_slots_and_completion():
    b = ContinuousBatcher(max_batch=2)
    reqs = [Request(np.arange(4), max_new_tokens=2) for _ in range(3)]
    for r in reqs:
        b.submit(r)
    admitted = b.admit()
    assert len(admitted) == 2 and b.waiting
    finished = b.step({0: 7, 1: 8})
    assert not finished
    finished = b.step({0: 9, 1: 10})
    assert len(finished) == 2
    admitted = b.admit()
    assert len(admitted) == 1 and admitted[0].slot in (0, 1)
    b.step({admitted[0].slot: 1})
    b.step({admitted[0].slot: 2})
    assert not b.has_work
    assert b.stats.completed == 3


def test_continuous_batcher_arrival_gated_admission_and_release():
    b = ContinuousBatcher(max_batch=2)
    early = Request(np.arange(4), max_new_tokens=1)
    late = Request(np.arange(4), max_new_tokens=1)
    early.arrival_s, late.arrival_s = 0.0, 5.0
    b.submit(early)
    b.submit(late)
    # at t=1 only the arrived request is admitted
    admitted = b.admit(now=1.0)
    assert admitted == [early] and len(b.waiting) == 1
    # release frees the slot outside the step() path
    early.output.append(3)
    b.release(early)
    assert early.slot not in b.active and b.stats.completed == 1
    # double-release is a no-op
    b.release(early)
    assert b.stats.completed == 1
    admitted = b.admit(now=6.0)
    assert admitted == [late] and not b.waiting


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.bfloat16),
                  {"c": jnp.zeros((2, 2), jnp.int32)}]}
    save_checkpoint(str(tmp_path / "ck"), tree, step=7)
    restored, step = load_checkpoint(str(tmp_path / "ck"), tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpointer_retention_and_restore(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, every=1)
    state = {"w": jnp.zeros((3,))}
    for s in range(1, 5):
        state = {"w": state["w"] + 1}
        ck.maybe_save(s, state, blocking=True)
    dirs = sorted(p.name for p in tmp_path.iterdir())
    assert dirs == ["step_3", "step_4"]
    restored, step = ck.restore_latest(state)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.full(3, 4.0))
