"""Property-based tests (hypothesis) on system invariants.

Skips cleanly when hypothesis is not installed (it is a dev-only extra,
see requirements-dev.txt); the deterministic seeded-fuzz variants in
`test_link_invariants.py` always run.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cache import TwoLevelLRU
from repro.core.prefetcher import Prefetcher, Transfer, TransferLink
from repro.core.step_size import (StepSizeConfig, StepSizeController,
                                  expected_active_experts)
from repro.models import moe as moe_mod

import jax.numpy as jnp


# ------------------------------------------------------------------ cache
@settings(max_examples=60, deadline=None)
@given(st.integers(2, 12),
       st.lists(st.tuples(st.integers(0, 5), st.integers(0, 15),
                          st.booleans()), min_size=1, max_size=120))
def test_cache_never_exceeds_capacity_and_eviction_prefers_low(cap, ops):
    c = TwoLevelLRU(cap)
    for layer, expert, high in ops:
        key = (layer, expert)
        if not c.touch(key, high=high):
            victim = c.insert(key, high=high)
            if victim is not None:
                assert victim not in c
        assert len(c) <= cap
        # tiers are disjoint
        assert not (set(c.high) & set(c.low))


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 30), min_size=1, max_size=60))
def test_cache_hits_iff_resident(keys):
    c = TwoLevelLRU(8)
    resident = set()
    for k in keys:
        key = (0, k)
        hit = c.touch(key)
        assert hit == (key in resident)
        if not hit:
            victim = c.insert(key)
            resident.add(key)
            if victim is not None:
                resident.discard(victim)
        assert resident == set(c.resident())


# ------------------------------------------------------------- controller
@settings(max_examples=40, deadline=None)
@given(st.lists(st.sampled_from(["stall", "over", "hit"]), max_size=200),
       st.integers(1, 6), st.integers(1, 10))
def test_step_size_always_in_bounds(events, st_thresh, of_thresh):
    cfg = StepSizeConfig(stall_threshold=st_thresh,
                         overfetch_threshold=of_thresh)
    c = StepSizeController(cfg=cfg, s=3)
    for e in events:
        if e == "stall":
            c.record_stall()
        elif e == "over":
            c.record_overfetch()
        assert cfg.s_min <= c.s <= cfg.s_max


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(1e-3, 1.0), min_size=2, max_size=32),
       st.floats(0.05, 0.95))
def test_expected_active_experts_monotone_in_threshold(probs, thresh):
    p = np.asarray(probs)
    n1 = expected_active_experts(p, thresh)
    n2 = expected_active_experts(p, min(thresh + 0.04, 0.99))
    assert 1 <= n1 <= len(probs)
    assert n2 >= n1


# ------------------------------------------------------------- transfer link
@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.floats(0.0, 5.0),
                          st.floats(1e5, 1e8)), min_size=1, max_size=40))
def test_link_serializes_and_respects_priorities(items):
    link = TransferLink(bandwidth=1e9)
    for i, (prio, t, nbytes) in enumerate(items):
        link.submit(Transfer((0, i), nbytes, prio, t))
    link.drain_until(1e9)
    done = [tr for tr in link.completed]
    assert len(done) == len(items)
    # non-overlap: transfers never overlap on the serial link
    done_sorted = sorted(done, key=lambda tr: tr.start_t)
    for a, b in zip(done_sorted, done_sorted[1:]):
        assert b.start_t >= a.done_t - 1e-9
    # each starts no earlier than issue
    for tr in done:
        assert tr.start_t >= tr.issue_t - 1e-12


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.floats(0.0, 5.0),
                          st.floats(1e5, 1e8)), min_size=1, max_size=40))
def test_link_completion_monotone_within_priority_class(items):
    """Within one priority class the link is FIFO: completion times are
    monotone in submit order."""
    link = TransferLink(bandwidth=1e9)
    for i, (prio, t, nbytes) in enumerate(items):
        link.submit(Transfer((prio, i), nbytes, prio, t))
    link.drain_until(1e12)
    for prio in (0, 1, 2):
        done = [tr for tr in link.completed if tr.priority == prio]
        done.sort(key=lambda tr: tr.key[1])       # submit order
        for a, b in zip(done, done[1:]):
            assert b.done_t >= a.done_t - 1e-9


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 2), st.floats(1e5, 1e7)),
                min_size=3, max_size=30),
       st.floats(0.0, 0.02), st.integers(0, 29))
def test_link_promote_never_reorders_in_flight_work(items, drain_t, pick):
    """promote() raises only *queued* transfers: transfers already started
    or completed keep their times, and non-promoted same-class transfers
    keep their relative order."""
    link = TransferLink(bandwidth=1e9)
    for i, (prio, nbytes) in enumerate(items):
        link.submit(Transfer((0, i), nbytes, prio, 0.0))
    link.drain_until(drain_t)
    before = {tr.key: tr.done_t for tr in link.completed}
    key = (0, pick % len(items))
    link.promote(key)
    link.drain_until(1e12)
    after = {tr.key: tr.done_t for tr in link.completed}
    for k, t in before.items():                   # in-flight work untouched
        assert after[k] == t
    for prio in (1, 2):                           # FIFO among non-promoted
        done = [tr for tr in link.completed
                if tr.priority == prio and tr.key != key]
        done.sort(key=lambda tr: tr.key[1])
        for a, b in zip(done, done[1:]):
            assert b.done_t >= a.done_t - 1e-9


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.floats(0.0, 2.0),
                          st.floats(1e5, 1e8)), min_size=1, max_size=30),
       st.integers(0, 29))
def test_link_finish_agrees_with_drain_until(items, pick):
    """finish(key) and drain_until(inf) assign identical done_t."""
    la, lb = TransferLink(1e9), TransferLink(1e9)
    for i, (prio, t, nbytes) in enumerate(items):
        la.submit(Transfer((0, i), nbytes, prio, t))
        lb.submit(Transfer((0, i), nbytes, prio, t))
    key = (0, pick % len(items))
    t_finish = la.finish(key, 0.0)
    lb.drain_until(1e12)
    t_drain = next(tr.done_t for tr in lb.completed if tr.key == key)
    assert t_finish == t_drain


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.floats(0.0, 5.0),
                          st.floats(1e5, 1e8)), min_size=1, max_size=40),
       st.lists(st.floats(0.0, 10.0), max_size=5))
def test_link_bytes_moved_accounts_completed_transfers(items, drains):
    link = TransferLink(bandwidth=1e9)
    for i, (prio, t, nbytes) in enumerate(items):
        link.submit(Transfer((0, i), nbytes, prio, t))
    for t in sorted(drains):
        link.drain_until(t)
        assert link.bytes_moved == pytest.approx(
            sum(tr.nbytes for tr in link.completed))
    link.drain_until(1e12)
    assert link.bytes_moved == pytest.approx(
        sum(tr.nbytes for tr in link.completed))
    assert len(link.completed) == len(items)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 30))
def test_prefetcher_demand_is_idempotent(n):
    link = TransferLink(1e9)
    pf = Prefetcher(link, 1e6)
    t1 = pf.demand((0, n), 0.0)
    t2 = pf.demand((0, n), 0.0)
    assert t1 == t2


# ------------------------------------------------------------- MoE invariants
@settings(max_examples=20, deadline=None)
@given(st.integers(1, 5), st.integers(2, 16), st.integers(1, 4),
       st.integers(0, 1000))
def test_router_gates_normalized_and_ids_unique(bt, experts, k, seed):
    import jax
    k = min(k, experts)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (bt * 4, 16))
    w = jax.random.normal(jax.random.fold_in(key, 1), (16, experts))
    r = moe_mod.route(w, x, k, norm_topk=True)
    gates = np.asarray(r.gates)
    ids = np.asarray(r.expert_ids)
    np.testing.assert_allclose(gates.sum(-1), 1.0, rtol=1e-5)
    for row in ids:
        assert len(set(row.tolist())) == len(row)
    assert (ids >= 0).all() and (ids < experts).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000))
def test_moe_grouped_matches_reference_without_drops(seed):
    import dataclasses
    import jax
    from repro.configs.base import MoEConfig
    moe = MoEConfig(num_experts=8, top_k=2, d_expert=16,
                    capacity_factor=4.0)   # drop-free
    key = jax.random.PRNGKey(seed)
    params = moe_mod.init_moe_params(key, 32, moe, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (24, 32)) * 0.3
    ref, _ = moe_mod.moe_reference(params, x, moe)
    got, _ = moe_mod.moe_grouped(params, x, moe)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_dispatch_plan_conserves_assignments(seed):
    import jax
    key = jax.random.PRNGKey(seed)
    ids = jax.random.randint(key, (32, 2), 0, 8)
    tok, eid, pos, keep, order = moe_mod.compute_dispatch(ids, 8, capacity=64)
    # every kept (token, expert) pair appears exactly once
    kept = [(int(t), int(e)) for t, e, k in
            zip(np.asarray(tok), np.asarray(eid), np.asarray(keep)) if k]
    orig = [(i, int(e)) for i, row in enumerate(np.asarray(ids))
            for e in row]
    assert sorted(kept) == sorted(orig)
    # positions within an expert are unique
    by_e = {}
    for e, p, k in zip(np.asarray(eid), np.asarray(pos), np.asarray(keep)):
        if k:
            by_e.setdefault(int(e), []).append(int(p))
    for plist in by_e.values():
        assert len(set(plist)) == len(plist)
