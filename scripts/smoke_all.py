"""Dev harness: forward + prefill + decode every smoke config, then a
fault lane — brownout-plan serving through the simulator mirror must
complete every request with retries firing (graceful degradation) — and
a tier lane — serving with a budgeted host staging tier must complete
every request while reporting disk->host promotion health."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.models import Model

B, T = 2, 16


def run(arch: str) -> None:
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    n = sum(x.size for x in jax.tree.leaves(params))
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    enc_out = None
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(key, (B, 24, cfg.d_model), jnp.bfloat16)
        enc_out = model.encode(params, frames)
    if cfg.uses_input_embeds:
        embeds = jax.random.normal(key, (B, T, cfg.d_model), jnp.bfloat16) * 0.02
        h = model.forward(params, embeds=embeds, enc_out=enc_out)
        logits_p, cache = model.prefill(params, embeds=embeds, max_seq=T + 8,
                                        enc_out=enc_out)
    else:
        h = model.forward(params, tokens, enc_out=enc_out)
        logits_p, cache = model.prefill(params, tokens, max_seq=T + 8,
                                        enc_out=enc_out)
    assert h.shape == (B, T, cfg.d_model), h.shape
    logits_f = model.logits(params, h[:, -1])
    assert jnp.isfinite(logits_f).all(), "forward logits NaN"
    assert jnp.isfinite(logits_p).all(), "prefill logits NaN"
    # prefill last-token logits must match forward last-token logits
    diff = jnp.max(jnp.abs(logits_f - logits_p))
    # decode one token, compare against forward of extended sequence
    nxt = jnp.argmax(logits_p, -1).astype(jnp.int32)
    logits_d, cache = model.decode_step(params, nxt, cache)
    assert jnp.isfinite(logits_d).all(), "decode logits NaN"
    if not cfg.uses_input_embeds:
        ext = jnp.concatenate([tokens, nxt[:, None]], axis=1)
        h2 = model.forward(params, ext, enc_out=enc_out)
        logits_ref = model.logits(params, h2[:, -1])
        ddiff = jnp.max(jnp.abs(logits_d - logits_ref))
    else:
        ddiff = -1.0
    print(f"{arch:24s} params={n/1e6:7.2f}M prefill_diff={diff:.4f} "
          f"decode_diff={float(ddiff):.4f}")


def run_fault_lane() -> None:
    """Brownout-plan serving on the simulator: every request must finish
    its token budget (no hangs) and retries must fire."""
    from repro.core.coordinator import ablation
    from repro.core.faults import FaultPlan
    from repro.simulator.events import SimSpec, StepTrace
    from repro.simulator.hardware import HardwareSpec
    from repro.simulator.serving import (ServingConfig, ServingRequest,
                                         ServingWorkload, simulate_serving)
    L, M, top_k, n_new = 2, 8, 2, 10
    reqs = []
    for rid in range(6):
        steps = []
        for si in range(n_new):
            assigns = [np.array([[(rid + si + li + j) % M]
                                 for j in range(top_k)])
                       for li in range(L)]
            steps.append(StepTrace(si, np.arange(4), assigns,
                                   np.zeros((L, 4), np.float32)))
        reqs.append(ServingRequest(prompt_len=16, max_new_tokens=n_new,
                                   steps=steps, request_id=rid))
    wl = ServingWorkload(L, M, top_k,
                         [np.zeros((4, M), np.float32) for _ in range(L)],
                         reqs, name="faults")
    hw = HardwareSpec("faultlane", host_bw=1e8, flops=1e15, hbm_bw=1e12,
                      mem_cap=1e9)
    spec = SimSpec(expert_bytes=1e5, layer_time_s=1e-3, capacity_experts=6)
    pol = ablation("faults", prefetch=True, adaptive_s=False,
                   two_level_lru=False, cache_aware=False,
                   blocking_swap_out=False, protect_early_layers=False)
    rep = simulate_serving(wl, spec, hw, pol, cfg=ServingConfig(
        max_batch=4, prefill_chunk=16, admission_cap=False,
        fault_plan=FaultPlan.brownout_preset(seed=0), retry_max=3))
    assert all(m.n_tokens == n_new for m in rep.requests), "request truncated"
    assert rep.n_retries > 0, "brownout plan fired no retries"
    print(f"fault lane: {len(rep.requests)} requests complete under "
          f"brownout (failures={rep.n_link_failures} "
          f"retries={rep.n_retries} degraded_steps={rep.n_degraded_steps})")


def run_tiers_lane() -> None:
    """Serving through the budgeted host staging tier: every request must
    finish, host-tier activity must show up, and the new tier health
    fields must be present in the ServingReport summary."""
    from repro.core.coordinator import ablation
    from repro.simulator.events import SimSpec, StepTrace
    from repro.simulator.hardware import HardwareSpec
    from repro.simulator.serving import (ServingConfig, ServingRequest,
                                         ServingWorkload, simulate_serving)
    L, M, top_k, n_new = 2, 8, 2, 10
    reqs = []
    for rid in range(6):
        steps = []
        for si in range(n_new):
            assigns = [np.array([[(rid + si + li + j) % M]
                                 for j in range(top_k)])
                       for li in range(L)]
            steps.append(StepTrace(si, np.arange(4), assigns,
                                   np.zeros((L, 4), np.float32)))
        reqs.append(ServingRequest(prompt_len=16, max_new_tokens=n_new,
                                   steps=steps, request_id=rid))
    wl = ServingWorkload(L, M, top_k,
                         [np.zeros((4, M), np.float32) for _ in range(L)],
                         reqs, name="tiers")
    hw = HardwareSpec("tierlane", host_bw=1e8, flops=1e15, hbm_bw=1e12,
                      mem_cap=1e9)
    spec = SimSpec(expert_bytes=1e5, layer_time_s=1e-3, capacity_experts=6)
    pol = ablation("tiers", prefetch=True, adaptive_s=False,
                   two_level_lru=False, cache_aware=False,
                   blocking_swap_out=False, protect_early_layers=False)
    rep = simulate_serving(wl, spec, hw, pol, cfg=ServingConfig(
        max_batch=4, prefill_chunk=16, admission_cap=False,
        host_budget_frac=0.5, disk_bandwidth=1e9, disk_prefetch=True))
    s = rep.summary()
    assert all(m.n_tokens == n_new for m in rep.requests), "request truncated"
    for k in ("n_host_hits", "n_host_misses", "disk_stall_s"):
        assert k in s, f"ServingReport summary missing tier field {k}"
    assert s["n_host_hits"] + s["n_host_misses"] > 0, "host tier never hit"
    print(f"tiers lane: {len(rep.requests)} requests complete through the "
          f"host staging tier (host_hits={s['n_host_hits']} "
          f"host_misses={s['n_host_misses']} "
          f"disk_stall={s['disk_stall_s'] * 1e3:.3f}ms)")


def run_integrity_lane() -> None:
    """Serving under seeded corruption chaos with verification on: every
    request must finish, corruption must be detected AND healed, and the
    integrity health fields must be present in the ServingReport summary."""
    from repro.core.coordinator import ablation
    from repro.core.faults import FaultPlan
    from repro.simulator.events import SimSpec, StepTrace
    from repro.simulator.hardware import HardwareSpec
    from repro.simulator.serving import (ServingConfig, ServingRequest,
                                         ServingWorkload, simulate_serving)
    L, M, top_k, n_new = 2, 8, 2, 10
    reqs = []
    for rid in range(6):
        steps = []
        for si in range(n_new):
            assigns = [np.array([[(rid + si + li + j) % M]
                                 for j in range(top_k)])
                       for li in range(L)]
            steps.append(StepTrace(si, np.arange(4), assigns,
                                   np.zeros((L, 4), np.float32)))
        reqs.append(ServingRequest(prompt_len=16, max_new_tokens=n_new,
                                   steps=steps, request_id=rid))
    wl = ServingWorkload(L, M, top_k,
                         [np.zeros((4, M), np.float32) for _ in range(L)],
                         reqs, name="integrity")
    hw = HardwareSpec("integlane", host_bw=1e8, flops=1e15, hbm_bw=1e12,
                      mem_cap=1e9)
    spec = SimSpec(expert_bytes=1e5, layer_time_s=1e-3, capacity_experts=6)
    pol = ablation("integrity", prefetch=True, adaptive_s=False,
                   two_level_lru=False, cache_aware=False,
                   blocking_swap_out=False, protect_early_layers=False)
    rep = simulate_serving(wl, spec, hw, pol, cfg=ServingConfig(
        max_batch=4, prefill_chunk=16, admission_cap=False,
        host_budget_frac=0.5, disk_bandwidth=1e9, disk_prefetch=True,
        fault_plan=FaultPlan.corrupt_flaky(seed=0), retry_max=3,
        verify="scrub", scrub_budget=2, refetch_max=3))
    s = rep.summary()
    assert all(m.n_tokens == n_new for m in rep.requests), "request truncated"
    for k in ("n_corrupt_detected", "n_requarantined", "n_scrubbed",
              "n_quarantined_experts"):
        assert k in s, f"ServingReport summary missing health field {k}"
    assert s["n_corrupt_detected"] > 0, "corrupt_flaky plan injected nothing"
    assert s["n_requarantined"] > 0, "no corrupt promotion ever healed"
    print(f"integrity lane: {len(rep.requests)} requests complete under "
          f"corruption chaos (detected={s['n_corrupt_detected']} "
          f"healed={s['n_requarantined']} scrubbed={s['n_scrubbed']} "
          f"quarantined={s['n_quarantined_experts']})")


if __name__ == "__main__":
    archs = sys.argv[1:] or ARCH_IDS
    for a in archs:
        try:
            run(a)
        except Exception as e:
            print(f"{a:24s} FAILED: {type(e).__name__}: {e}")
            import traceback
            traceback.print_exc()
    run_fault_lane()
    run_tiers_lane()
    run_integrity_lane()
