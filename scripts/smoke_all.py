"""Dev harness: forward + prefill + decode every smoke config."""
import sys

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.models import Model

B, T = 2, 16


def run(arch: str) -> None:
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    n = sum(x.size for x in jax.tree.leaves(params))
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    enc_out = None
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(key, (B, 24, cfg.d_model), jnp.bfloat16)
        enc_out = model.encode(params, frames)
    if cfg.uses_input_embeds:
        embeds = jax.random.normal(key, (B, T, cfg.d_model), jnp.bfloat16) * 0.02
        h = model.forward(params, embeds=embeds, enc_out=enc_out)
        logits_p, cache = model.prefill(params, embeds=embeds, max_seq=T + 8,
                                        enc_out=enc_out)
    else:
        h = model.forward(params, tokens, enc_out=enc_out)
        logits_p, cache = model.prefill(params, tokens, max_seq=T + 8,
                                        enc_out=enc_out)
    assert h.shape == (B, T, cfg.d_model), h.shape
    logits_f = model.logits(params, h[:, -1])
    assert jnp.isfinite(logits_f).all(), "forward logits NaN"
    assert jnp.isfinite(logits_p).all(), "prefill logits NaN"
    # prefill last-token logits must match forward last-token logits
    diff = jnp.max(jnp.abs(logits_f - logits_p))
    # decode one token, compare against forward of extended sequence
    nxt = jnp.argmax(logits_p, -1).astype(jnp.int32)
    logits_d, cache = model.decode_step(params, nxt, cache)
    assert jnp.isfinite(logits_d).all(), "decode logits NaN"
    if not cfg.uses_input_embeds:
        ext = jnp.concatenate([tokens, nxt[:, None]], axis=1)
        h2 = model.forward(params, ext, enc_out=enc_out)
        logits_ref = model.logits(params, h2[:, -1])
        ddiff = jnp.max(jnp.abs(logits_d - logits_ref))
    else:
        ddiff = -1.0
    print(f"{arch:24s} params={n/1e6:7.2f}M prefill_diff={diff:.4f} "
          f"decode_diff={float(ddiff):.4f}")


if __name__ == "__main__":
    archs = sys.argv[1:] or ARCH_IDS
    for a in archs:
        try:
            run(a)
        except Exception as e:
            print(f"{a:24s} FAILED: {type(e).__name__}: {e}")
            import traceback
            traceback.print_exc()
